"""Observability layer (repro.obs): metrics registry round-trip, trace-span
nesting + Chrome export, the no-op fast path, explain() rendering across the
mask x route grid, and the PR's sharded-deployment acceptance scenario."""
import dataclasses
import json
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core import (ANY_OVERLAP, EngineConfig, QueryEngine,
                        SearchRequest, intervals as iv)
from repro.obs.metrics import MetricsRegistry, StreamingHistogram
from repro.obs.trace import Tracer
from repro.data import make_queries


def _req(ds, qlo, qhi, mask=ANY_OVERLAP, **kw):
    return SearchRequest(ds.queries, (qlo, qhi), mask, k=5, ef=48, **kw)


class FakeClock:
    """Deterministic clock: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.001
        return self.t


# ---- metrics registry ------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests", labels=("route",))
    c.inc(route="graph")
    c.inc(2.0, route="graph")
    c.labels(route="flat").inc()
    assert c.value(route="graph") == 3.0
    assert c.value(route="flat") == 1.0
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.inc(-2)
    assert g.value() == 5.0
    h = reg.histogram("lat_ms", "latency", labels=("op",))
    for v in (1.0, 2.0, 100.0):
        h.observe(v, op="search")
    assert h.labels(op="search").count == 3
    assert h.percentile(50, op="search") >= 1.0


def test_registry_get_or_create_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x", "help", labels=("route",))
    assert reg.counter("x", labels=("route",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")
    with pytest.raises(ValueError, match="labels"):
        reg.counter("x", labels=("shard",))
    with pytest.raises(ValueError, match="expected labels"):
        a.inc(shard="0")


def test_snapshot_round_trip():
    reg = MetricsRegistry()
    reg.counter("reqs", "total", labels=("route",)).inc(5, route="graph")
    reg.gauge("inflight", "rows").set(12.5)
    h = reg.histogram("lat_ms", "latency", labels=("op",), lo_ms=0.1,
                      hi_ms=1e3, bins=32)
    for v in (0.5, 3.0, 40.0, 900.0, 5e4):   # last clamps to edge bin
        h.observe(v, op="tick")
    snap = reg.snapshot()
    json.dumps(snap)                          # JSON-stable
    assert snap["schema"] == 1
    reg2 = MetricsRegistry.from_snapshot(snap)
    assert reg2.snapshot() == snap            # bit-for-bit round-trip
    assert reg2.counter("reqs", labels=("route",)).value(route="graph") == 5
    h2 = reg2.get("lat_ms").labels(op="tick")
    assert h2.count == 5 and h2.percentile(95) == h.percentile(95, op="tick")


def test_snapshot_rejects_unknown_schema():
    with pytest.raises(ValueError, match="schema"):
        MetricsRegistry.from_snapshot({"schema": 99, "metrics": {}})


def test_render_prometheus():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "requests", labels=("route",)).inc(3,
                                                                 route="graph")
    h = reg.histogram("lat_ms", "latency", lo_ms=1.0, hi_ms=100.0, bins=8)
    h.observe(2.0)
    h.observe(50.0)
    text = reg.render_prometheus()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{route="graph"} 3' in text
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert "lat_ms_count 2" in text
    # cumulative bucket counts never decrease
    buckets = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
               if line.startswith("lat_ms_bucket")]
    assert buckets == sorted(buckets)


def test_metrics_http_server():
    reg = MetricsRegistry()
    reg.counter("pings", "scrapes").inc(4)
    server = obs.start_metrics_server(0, registry=reg)
    try:
        host, port = server.server_address[:2]
        text = urllib.request.urlopen(
            f"http://{host}:{port}/metrics").read().decode()
        assert "pings 4" in text
        snap = json.loads(urllib.request.urlopen(
            f"http://{host}:{port}/metrics.json").read().decode())
        assert MetricsRegistry.from_snapshot(snap).counter(
            "pings").value() == 4
    finally:
        server.shutdown()


def test_streaming_histogram_compat_reexport():
    # StreamingHistogram moved to repro.obs (PR 7); the serving import path
    # must keep resolving to the same class
    from repro.serving.scheduler import StreamingHistogram as Compat
    assert Compat is StreamingHistogram


# ---- trace spans -----------------------------------------------------------

def test_span_nesting_and_walk():
    with obs.capture(clock=FakeClock()) as tr:
        with obs.span("outer") as o:
            o.set("Q", 4)
            with obs.span("inner_a"):
                pass
            with obs.span("inner_b"):
                with obs.span("leaf"):
                    pass
    trace = tr.trace()
    assert trace.span_names() == ["outer", "inner_a", "inner_b", "leaf"]
    assert [d for _, d in trace.walk()] == [0, 1, 1, 2]
    assert len(trace) == 4


def test_chrome_export_golden():
    tracer = Tracer(clock=FakeClock())           # t0 = 1 ms
    a = tracer.span("a")                         # start 2 ms
    b = tracer.span("b").set("k", 1)             # start 3 ms
    b.stop()                                     # stop 4 ms
    a.stop()                                     # stop 5 ms
    chrome = tracer.trace().to_chrome()
    assert chrome == {
        "displayTimeUnit": "ms",
        "traceEvents": [
            {"name": "a", "cat": "repro", "ph": "X", "ts": 1000.0,
             "dur": 3000.0, "pid": 0, "tid": 0, "args": {}},
            {"name": "b", "cat": "repro", "ph": "X", "ts": 2000.0,
             "dur": 1000.0, "pid": 0, "tid": 0, "args": {"k": 1}},
        ],
    }


def test_out_of_order_stop_unwinds():
    tracer = Tracer(clock=FakeClock())
    outer = tracer.span("outer")
    inner = tracer.span("inner")
    outer.stop()                  # stops inner too (explicit-region contract)
    assert inner.t_stop is not None
    assert tracer._stack == []
    tracer.span("next").stop()    # new span is a fresh root, not a child
    assert [sp.name for sp in tracer.roots] == ["outer", "next"]


def test_noop_fast_path():
    assert not obs.tracing()
    sp = obs.span("anything")
    assert sp is obs.NULL_SPAN                  # singleton, no allocation
    assert sp.set("k", 1) is sp and sp.stop() is sp
    with obs.span("ctx") as c:
        assert c is obs.NULL_SPAN
    # overhead smoke: the disabled path must stay sub-10us per span (it is
    # one thread-local read; the bound is lenient for noisy CI boxes)
    import time
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("noop") as s:
            s.set("k", 1)
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 10e-6, f"no-op span path cost {per_call * 1e6:.2f} us"


def test_begin_end_request_trace_nesting():
    t = obs.begin_request_trace()
    assert t is not None and obs.tracing()
    assert obs.begin_request_trace() is None     # inner layer joins, not owns
    assert obs.end_request_trace(None) is None   # inner passthrough
    obs.span("work").stop()
    trace = obs.end_request_trace(t)
    assert not obs.tracing()
    assert trace.span_names() == ["work"]


# ---- engine integration ----------------------------------------------------

def test_engine_trace_on_request(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    res = eng.search(_req(ds, qlo, qhi, trace=True))
    assert res.trace is not None
    names = res.trace.span_names()
    assert names[0] == "search"
    assert "route" in names and "plan" in names
    json.loads(res.trace.to_json())              # valid Chrome JSON
    # default path stays untraced and leaves no tracer behind
    res_off = eng.search(_req(ds, qlo, qhi))
    assert res_off.trace is None and not obs.tracing()
    np.testing.assert_array_equal(res.ids, res_off.ids)


def test_engine_trace_sample(small_ds, built_index):
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    eng = QueryEngine(built_index, config=EngineConfig(trace_sample=0.5))
    traced = [eng.search(_req(ds, qlo, qhi)).trace is not None
              for _ in range(4)]
    assert traced == [False, True, False, True]
    with pytest.raises(ValueError, match="trace_sample"):
        EngineConfig(trace_sample=1.5)


def test_engine_metrics_recorded(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    reqs = obs.get_registry().counter("engine_requests_total",
                                      labels=("route",))
    lat = obs.get_registry().get("engine_search_ms")
    before = reqs.value(route="pruned")
    before_n = lat.labels(route="pruned").count
    eng.search(_req(ds, qlo, qhi, route="pruned"))
    assert reqs.value(route="pruned") == before + 1
    assert lat.labels(route="pruned").count == before_n + 1


def test_explain_mask_route_grid(small_ds, built_index):
    """explain() renders on every (mask, route) cell without tracing."""
    ds = small_ds
    eng = QueryEngine(built_index)
    masks = (1, 2, 3, 4, 8, 10, 12, ANY_OVERLAP)
    assert len(set(masks)) == 8
    for mask in masks:
        qlo, qhi = make_queries(ds, mask, 0.15, seed=31)
        for route in ("graph", "pruned", "flat"):
            res = eng.search(_req(ds, qlo, qhi, mask, route=route))
            text = res.explain()
            assert f"route: {route}" in text, (iv.mask_name(mask), route)
            assert "trace: (none" in text
    # and one traced cell renders the span tree inline
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    text = eng.search(_req(ds, qlo, qhi, route="graph", trace=True)).explain()
    assert "trace:" in text and "search" in text


# ---- acceptance: sharded deployment ---------------------------------------

def test_sharded_trace_acceptance(small_ds, tmp_path):
    """SearchRequest(trace=True) through engine_auto on a 2-shard host-merge
    deployment -> valid Chrome-trace JSON covering plan / route / per-shard
    search / merge, with explain() printing the same breakdown."""
    from repro.core import IndexSpec
    from repro.distributed import DeploymentSpec, ShardedDeployment
    ds = small_ds
    dep = ShardedDeployment.build(
        ds.vectors, ds.lo, ds.hi, mesh=None,
        spec=DeploymentSpec(n_shards=2,
                            index=IndexSpec(variants=("T", "Tp"), m=8,
                                            ef_con=40)))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    res = dep.execute(_req(ds, qlo, qhi, trace=True))   # route=None -> auto
    assert res.trace is not None
    names = res.trace.span_names()
    for want in ("sharded_search", "plan", "shard-0", "shard-1", "merge",
                 "search", "route"):
        assert want in names, names
    path = res.trace.save(str(tmp_path / "trace.json"))
    with open(path) as f:
        chrome = json.load(f)
    events = chrome["traceEvents"]
    assert events and all(e["ph"] == "X" for e in events)
    assert {e["name"] for e in events} == set(names)
    text = res.explain()
    assert "shard[0]" in text and "shard[1]" in text
    assert "merge: host" in text and "sharded_search" in text
    # inner shard engines joined the outer trace: exactly one Trace, and the
    # per-shard engine spans nest under their shard span
    shard0 = next(sp for sp in res.trace.roots[0].children
                  if sp.name == "shard-0")
    assert [c.name for c in shard0.children] == ["search"]


# ---- serving: one snapshot schema from both servers ------------------------

def test_sync_async_snapshot_schema(small_ds, built_index):
    from repro.serving import (AsyncRetrievalServer, RetrievalServer,
                               SLOPolicy)
    ds = small_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.15, seed=31)
    embed = lambda items: ds.queries[np.asarray(items)]  # noqa: E731

    sync = RetrievalServer(QueryEngine(built_index), embed, k=5, ef=48)
    for i in range(6):
        sync.submit(i, qlo[i], qhi[i], ANY_OVERLAP)
    sync.tick()
    ssnap = sync.snapshot()

    asyn = AsyncRetrievalServer(QueryEngine(built_index), embed, k=5, ef=48,
                                policy=SLOPolicy(max_wait_ms=1.0,
                                                 max_batch=8))
    for i in range(6):
        asyn.submit(i, qlo[i], qhi[i], ANY_OVERLAP)
    asyn.run_until_idle()
    asnap = asyn.snapshot()

    # exp13 reads ONE schema from both servers
    assert set(ssnap) - set(asnap) == set()
    for snap in (ssnap, asnap):
        assert snap["served"] == 6
        assert set(snap["queue_wait_ms"]) == set(snap["e2e_ms"])
        assert snap["e2e_ms"]["p95"] >= snap["queue_wait_ms"]["p50"] >= 0.0


# ---- log + profile ---------------------------------------------------------

def test_progress_rate_limit():
    from repro.obs.log import get_logger
    lg = get_logger("test_obs_progress")
    assert lg.progress("tick", every_s=60.0, done=1) is True
    assert lg.progress("tick", every_s=60.0, done=2) is False   # rate-limited
    assert lg.progress("tick", every_s=60.0, done=3, final=True) is True
    assert lg.progress("other", every_s=60.0) is True           # per-event


def test_bandwidth_annotation():
    from repro.obs.profile import HBM_BW, bandwidth_annotation
    ann = bandwidth_annotation(HBM_BW, 1.0)      # one peak-second of bytes
    assert ann["frac_of_peak"] == pytest.approx(1.0)
    assert ann["gb_per_s"] == pytest.approx(HBM_BW / 1e9)
    assert bandwidth_annotation(1024, 0.0)["gb_per_s"] == 0.0


def test_kernel_span_records_bandwidth(small_ds):
    import jax.numpy as jnp
    from repro.kernels import ops
    ds = small_ds
    q = jnp.asarray(ds.queries[:2])
    cand = jnp.asarray(np.broadcast_to(ds.vectors[:8],
                                       (2, 8, ds.vectors.shape[1])).copy())
    ref = np.asarray(ops.gathered_l2(q, cand))   # untraced
    t = obs.begin_request_trace()
    traced = np.asarray(ops.gathered_l2(q, cand))
    trace = obs.end_request_trace(t)
    np.testing.assert_allclose(traced, ref)
    sp = trace.roots[0]
    assert sp.name == "kernel:gathered_l2"
    assert {"bytes", "gb_per_s", "frac_of_peak"} <= set(sp.args)
