"""Quantized vector tier: QuantizedStore round-trip bounds, int8 kernel vs
oracle, compressed scan + exact re-rank exactness, quantized-vs-float32
recall parity over an 8-mask x 3-route grid (drop <= 0.01), save/load
bit-identity, pre-knob artifact compatibility, quantized streaming
compaction vs a static quantized build, tier-aware routing, and the
per-kernel byte models."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ANY_OVERLAP, EngineConfig, IndexSpec, MSTGIndex,
                        QueryEngine, SearchRequest, intervals as iv)
from repro.core.compressed import (NO_EDGE, compressed_flat_topr,
                                   exact_rerank, topr_from_dists)
from repro.core.quant import (STORAGE_DTYPES, QuantizedStore,
                              check_storage_dtype, maybe_quantize)
from repro.data import (brute_force_topk, make_queries, make_range_dataset,
                        recall_at_k)
from repro.kernels import ops
from repro.kernels.ref import (gathered_topk_quant_ref, gathered_topk_ref,
                               pairwise_l2_int8_ref, pairwise_l2_masked_ref)

# same 8-mask acceptance grid as the streaming equivalence suite: every
# atomic RR case, disjunctions, and the containment masks
MASKS8 = (1, 2, 4, 8, 15, 16, 32, 48)
ROUTES = ("graph", "pruned", "flat")
RECALL_DROP_MAX = 0.01


# ---- QuantizedStore -------------------------------------------------------

def test_check_storage_dtype():
    assert check_storage_dtype(None) == "float32"
    for d in STORAGE_DTYPES:
        assert check_storage_dtype(d) == d
    with pytest.raises(ValueError, match="storage_dtype"):
        check_storage_dtype("int4")


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    v = rng.normal(0, 3, (400, 24)).astype(np.float32)
    v[:, 3] = 7.5  # constant dimension must reconstruct exactly
    st = QuantizedStore.from_vectors(v, "int8")
    assert st.codes.dtype == np.int8 and st.itemsize == 1
    err = np.abs(st.dequantize() - v)
    # affine min/max quantization: per-dim error is at most half a step
    assert np.all(err <= st.scale[None, :] * 0.5 + 1e-5)
    np.testing.assert_allclose(st.dequantize()[:, 3], 7.5, atol=1e-5)
    # sq_norm is the norm of the *reconstruction* (what the scan adds back)
    deq = st.dequantize()
    np.testing.assert_allclose(st.sq_norm, np.einsum("nd,nd->n", deq, deq),
                               rtol=1e-5)


def test_float16_tier_identity_affine():
    rng = np.random.default_rng(1)
    v = rng.normal(0, 1, (100, 8)).astype(np.float32)
    st = QuantizedStore.from_vectors(v, "float16")
    assert st.codes.dtype == np.float16 and st.itemsize == 2
    np.testing.assert_array_equal(st.scale, np.ones(8, np.float32))
    np.testing.assert_array_equal(st.offset, np.zeros(8, np.float32))
    np.testing.assert_allclose(st.dequantize(), v, atol=2e-3)


def test_maybe_quantize_float32_is_none():
    v = np.zeros((4, 4), np.float32)
    assert maybe_quantize(v, "float32") is None
    assert maybe_quantize(v, None) is None
    assert maybe_quantize(v, "int8") is not None


# ---- byte models ----------------------------------------------------------

def test_pairwise_stream_bytes_model():
    Q, N, d = 8, 1000, 64
    for itemsize in (1, 2, 4):
        got = ops.pairwise_stream_bytes(Q, N, d, itemsize)
        want = N * d * itemsize + Q * d * 4 + 2 * N * 4 + 2 * Q * 4
        assert got == want
    # the compression lever: table bytes shrink 4x, the rest is unchanged
    f32 = ops.pairwise_stream_bytes(Q, N, d, 4)
    i8 = ops.pairwise_stream_bytes(Q, N, d, 1)
    assert f32 - i8 == N * d * 3


def test_gathered_stream_bytes_model():
    Q, M, L, d = 8, 24, 32, 64
    got = ops.gathered_stream_bytes(Q, M, L, d, 1)
    want = (Q * d * 4 + Q * M * d * 1 + Q * M * 16 + Q * 4
            + 2 * Q * L * 12)
    assert got == want
    # gathers touch Q*M candidate rows, never the whole table
    assert ops.gathered_stream_bytes(Q, M, L, d, 4) - got == Q * M * d * 3


def test_storage_bytes_accounting():
    rng = np.random.default_rng(2)
    v = rng.normal(0, 1, (300, 16)).astype(np.float32)
    lo = rng.uniform(0, 50, 300)
    hi = lo + rng.uniform(0, 10, 300)
    idx = MSTGIndex(v, lo, hi, variants=("T",), m=8, ef_con=32,
                    storage_dtype="int8")
    sb = idx.storage_bytes()
    assert sb["storage_dtype"] == "int8"
    assert sb["scan_bytes"] == sb["codes"] + sb["scales"] + sb["sq_norm"]
    assert sb["codes"] == 300 * 16  # 1 byte/component
    assert sb["float32_rerank"] == v.nbytes
    np.testing.assert_allclose(sb["compression_ratio"],
                               v.nbytes / sb["scan_bytes"])
    f32 = MSTGIndex(v, lo, hi, variants=("T",), m=8, ef_con=32)
    sbf = f32.storage_bytes()
    assert sbf["codes"] == 0 and sbf["compression_ratio"] == 1.0
    assert sbf["scan_bytes"] == v.nbytes


# ---- EngineConfig validation ----------------------------------------------

def test_engine_config_validation():
    EngineConfig(storage_dtype="int8", rerank_k=32)  # valid
    with pytest.raises(ValueError, match="storage_dtype"):
        EngineConfig(storage_dtype="bf16")
    with pytest.raises(ValueError, match="rerank_k"):
        EngineConfig(rerank_k=0)


# ---- kernels vs oracles ---------------------------------------------------

@pytest.mark.parametrize("mask", (1, 15, 48))
@pytest.mark.parametrize("Q,N,d", [(4, 96, 16), (5, 130, 24), (8, 256, 32)])
def test_pairwise_l2_int8_matches_ref(mask, Q, N, d):
    """Pallas int8 kernel (interpret mode) == jnp oracle, including on
    unaligned shapes the kernel must pad internally."""
    rng = np.random.default_rng(mask * 100 + N)
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    v = rng.normal(0, 2, (N, d)).astype(np.float32)
    st = QuantizedStore.from_vectors(v, "int8")
    lo = rng.uniform(0, 100, N).astype(np.float32)
    hi = lo + rng.uniform(0, 30, N).astype(np.float32)
    ql = rng.uniform(0, 80, Q).astype(np.float32)
    qh = ql + rng.uniform(0, 40, Q).astype(np.float32)
    got = np.asarray(ops.pairwise_l2_int8(q, st.codes, st.scale, st.offset,
                                          st.sq_norm, lo, hi, ql, qh, mask))
    want = np.asarray(pairwise_l2_int8_ref(
        jnp.asarray(q), jnp.asarray(st.codes), jnp.asarray(st.scale),
        jnp.asarray(st.offset), jnp.asarray(st.sq_norm), jnp.asarray(lo),
        jnp.asarray(hi), jnp.asarray(ql), jnp.asarray(qh), mask))
    fin = np.isfinite(want)
    np.testing.assert_array_equal(np.isfinite(got), fin)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-3)


def test_pairwise_l2_int8_close_to_exact():
    """Quantized distances track the exact float32 distances to within the
    quantization error budget (loose bound; the engine's re-rank removes
    the residual)."""
    rng = np.random.default_rng(7)
    Q, N, d = 4, 128, 16
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    v = rng.normal(0, 1, (N, d)).astype(np.float32)
    st = QuantizedStore.from_vectors(v, "int8")
    lo = np.zeros(N, np.float32)
    hi = np.ones(N, np.float32)
    ql = np.zeros(Q, np.float32)
    qh = np.ones(Q, np.float32)
    approx = np.asarray(pairwise_l2_int8_ref(
        jnp.asarray(q), jnp.asarray(st.codes), jnp.asarray(st.scale),
        jnp.asarray(st.offset), jnp.asarray(st.sq_norm), jnp.asarray(lo),
        jnp.asarray(hi), jnp.asarray(ql), jnp.asarray(qh), ANY_OVERLAP))
    exact = np.asarray(pairwise_l2_masked_ref(
        jnp.asarray(q), jnp.asarray(v), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(ql), jnp.asarray(qh), ANY_OVERLAP))
    assert np.max(np.abs(approx - exact)) < 0.5


@pytest.mark.parametrize("dtype", ("int8", "float16"))
def test_gathered_topk_quant_matches_ref(dtype):
    rng = np.random.default_rng(3)
    Q, n, d, M, L = 4, 200, 16, 12, 16
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    table = rng.normal(0, 1, (n, d)).astype(np.float32)
    st = QuantizedStore.from_vectors(table, dtype)
    ids = rng.integers(0, n, (Q, M)).astype(np.int32)
    avail = (rng.random((Q, M)) < 0.8).astype(np.int32)
    b = np.zeros((Q, M), np.int32)
    e = np.full((Q, M), 10 ** 6, np.int32)
    ver = np.zeros(Q, np.int32)
    pool_d = np.sort(rng.random((Q, L)).astype(np.float32), axis=1)
    pool_ids = rng.integers(0, n, (Q, L)).astype(np.int32)
    pool_exp = np.zeros((Q, L), bool)
    got = ops.gathered_topk_quant(q, st.codes, st.scale, st.offset, ids,
                                  avail, b, e, ver, pool_ids, pool_d,
                                  pool_exp)
    want = gathered_topk_quant_ref(
        jnp.asarray(q), jnp.asarray(st.codes), jnp.asarray(st.scale),
        jnp.asarray(st.offset), jnp.asarray(ids), jnp.asarray(avail),
        jnp.asarray(b), jnp.asarray(e), jnp.asarray(ver),
        jnp.asarray(pool_ids), jnp.asarray(pool_d), jnp.asarray(pool_exp))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_gathered_topk_quant_ref_is_dequantized_f32_step():
    """The quant oracle is *defined* as the float32 oracle over the
    dequantized table — pin that equivalence."""
    rng = np.random.default_rng(4)
    Q, n, d, M, L = 2, 64, 8, 6, 8
    table = rng.normal(0, 1, (n, d)).astype(np.float32)
    st = QuantizedStore.from_vectors(table, "int8")
    q = rng.normal(0, 1, (Q, d)).astype(np.float32)
    ids = rng.integers(0, n, (Q, M)).astype(np.int32)
    avail = np.ones((Q, M), np.int32)
    b = np.zeros((Q, M), np.int32)
    e = np.full((Q, M), 10 ** 6, np.int32)
    ver = np.zeros(Q, np.int32)
    pool_d = np.full((Q, L), np.inf, np.float32)
    pool_ids = np.full((Q, L), NO_EDGE, np.int32)
    pool_exp = np.zeros((Q, L), bool)
    args = (jnp.asarray(ids), jnp.asarray(avail), jnp.asarray(b),
            jnp.asarray(e), jnp.asarray(ver), jnp.asarray(pool_ids),
            jnp.asarray(pool_d), jnp.asarray(pool_exp))
    got = gathered_topk_quant_ref(jnp.asarray(q), jnp.asarray(st.codes),
                                  jnp.asarray(st.scale),
                                  jnp.asarray(st.offset), *args)
    want = gathered_topk_ref(jnp.asarray(q), jnp.asarray(st.dequantize()),
                             *args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5,
                                   atol=1e-5)


# ---- compressed scan + exact re-rank --------------------------------------

def test_compressed_topr_plus_rerank_is_exact():
    """With R = n the candidate list trivially contains the true neighbors,
    so the re-ranked result must equal the float32 brute force bit for bit
    (ids and distances)."""
    rng = np.random.default_rng(5)
    n, d, Q, k = 300, 16, 6, 5
    ds = make_range_dataset(n=n, d=d, n_queries=Q, quantize=16, seed=5)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=6)
    st = QuantizedStore.from_vectors(ds.vectors, "int8")
    codes_t = np.ascontiguousarray(st.codes.T)
    ids, dists = compressed_flat_topr(
        jnp.asarray(codes_t), jnp.asarray(st.scale), jnp.asarray(st.offset),
        jnp.asarray(st.sq_norm), jnp.asarray(ds.lo, jnp.float32),
        jnp.asarray(ds.hi, jnp.float32), jnp.asarray(ds.queries),
        jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32),
        mask=ANY_OVERLAP, rerank=n, block=128)
    ids = np.asarray(ids)
    rows = ds.vectors[np.clip(ids, 0, None)]
    rid, rd = exact_rerank(jnp.asarray(ds.queries), jnp.asarray(rows),
                           jnp.asarray(ids), k=k)
    tids, tdists = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                                    qlo, qhi, ANY_OVERLAP, k)
    np.testing.assert_array_equal(np.asarray(rid), tids)
    np.testing.assert_allclose(np.asarray(rd)[tids >= 0],
                               tdists[tids >= 0], rtol=1e-4, atol=1e-4)


def test_topr_from_dists_padding():
    d = jnp.asarray([[0.5, jnp.inf, 0.1, jnp.inf]])
    ids, dd = topr_from_dists(d, rerank=3)
    assert np.asarray(ids)[0, 0] == 2 and np.asarray(ids)[0, 1] == 0
    assert np.asarray(ids)[0, 2] == NO_EDGE
    assert not np.isfinite(np.asarray(dd)[0, 2])


# ---- recall parity grid (acceptance: drop <= 0.01, 8 masks x 3 routes) ----

@pytest.fixture(scope="module")
def parity_ds():
    return make_range_dataset(n=900, d=16, n_queries=10, quantize=32, seed=9)


@pytest.fixture(scope="module")
def parity_engines(parity_ds):
    ds = parity_ds
    out = {}
    for tier in ("float32", "int8", "float16"):
        idx = MSTGIndex(ds.vectors, ds.lo, ds.hi,
                        variants=("T", "Tp", "Tpp"), m=8, ef_con=40,
                        storage_dtype=tier)
        out[tier] = QueryEngine(idx, config=EngineConfig())
    return out


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("mask", MASKS8)
def test_quantized_recall_parity(parity_ds, parity_engines, mask, route):
    ds = parity_ds
    qlo, qhi = make_queries(ds, mask, 0.2, seed=mask)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, mask, 5)
    req = SearchRequest(ds.queries, (qlo, qhi), mask, k=5, ef=48, route=route)
    base = recall_at_k(np.asarray(parity_engines["float32"].search(req).ids),
                       tids)
    for tier in ("int8", "float16"):
        r = recall_at_k(np.asarray(parity_engines[tier].search(req).ids),
                        tids)
        assert base - r <= RECALL_DROP_MAX, \
            f"{tier}/{iv.mask_name(mask)}/{route}: {base} -> {r}"


def test_exact_routes_stay_exact_under_quantization(parity_ds,
                                                    parity_engines):
    """flat and pruned are exhaustive over qualifying rows; with the exact
    re-rank the quantized tiers must return recall-1.0-equivalent ids, not
    merely within the drop budget."""
    ds = parity_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=77)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 5)
    for route in ("flat", "pruned"):
        req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5,
                            route=route)
        for tier in ("int8", "float16"):
            got = np.asarray(parity_engines[tier].search(req).ids)
            assert recall_at_k(got, tids) == 1.0, f"{tier}/{route}"


# ---- tier-aware routing ----------------------------------------------------

def test_router_scan_cost_ratio(parity_engines):
    assert parity_engines["float32"]._scan_cost_ratio == 1.0
    assert parity_engines["int8"]._scan_cost_ratio == 0.25
    assert parity_engines["float16"]._scan_cost_ratio == 0.5


def test_auto_route_works_quantized(parity_ds, parity_engines):
    ds = parity_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=13)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 5)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5, ef=48)
    res = parity_engines["int8"].search(req)
    assert res.report.route in ROUTES
    assert recall_at_k(np.asarray(res.ids), tids) >= 0.95


def test_rerank_k_knob(parity_ds):
    """rerank_k=k degenerates to trusting the approximate order; a wider
    budget can only help. Both must stay within the drop budget on flat."""
    ds = parity_ds
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=21)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 5)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=(),
                    storage_dtype="int8")
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5,
                        route="flat")
    r_narrow = recall_at_k(np.asarray(QueryEngine(
        idx, config=EngineConfig(rerank_k=5)).search(req).ids), tids)
    r_wide = recall_at_k(np.asarray(QueryEngine(
        idx, config=EngineConfig(rerank_k=64)).search(req).ids), tids)
    assert r_wide >= r_narrow
    assert r_wide >= 1.0 - RECALL_DROP_MAX


# ---- persistence -----------------------------------------------------------

def test_save_load_bit_identity(tmp_path, parity_ds):
    ds = parity_ds
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"), m=8,
                    ef_con=40, storage_dtype="int8")
    path = idx.save(str(tmp_path / "quant.npz"))
    loaded = MSTGIndex.load(path)
    assert loaded.spec.storage_dtype == "int8"
    np.testing.assert_array_equal(loaded.storage.codes, idx.storage.codes)
    np.testing.assert_array_equal(loaded.storage.scale, idx.storage.scale)
    np.testing.assert_array_equal(loaded.storage.offset, idx.storage.offset)
    np.testing.assert_array_equal(loaded.storage.sq_norm,
                                  idx.storage.sq_norm)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=31)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5,
                        route="flat")
    a = QueryEngine(idx).search(req)
    b = QueryEngine(loaded).search(req)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_pre_knob_artifact_loads_as_float32(parity_ds):
    """Artifacts written before the storage tier existed carry neither the
    spec field nor code arrays — they must load (as float32) and serve."""
    ds = parity_ds
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T",), m=8,
                    ef_con=40)
    arrays, meta = idx.to_payload()
    spec_d = dict(meta["spec"])
    spec_d.pop("storage_dtype")
    old = MSTGIndex.from_payload(dict(arrays), {**meta, "spec": spec_d})
    assert old.spec.storage_dtype == "float32"
    assert old.storage is None
    eng = QueryEngine(old)
    assert eng.storage_dtype == "float32"


def test_quantized_spec_without_code_arrays_requantizes(parity_ds):
    """A quantized spec whose payload lost the code arrays re-quantizes
    deterministically from the float32 corpus (same min/max, same codes)."""
    ds = parity_ds
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T",), m=8,
                    ef_con=40, storage_dtype="int8")
    arrays, meta = idx.to_payload()
    for key in ("codes", "code_scale", "code_offset", "code_sq_norm"):
        arrays.pop(key)
    re = MSTGIndex.from_payload(dict(arrays), meta)
    np.testing.assert_array_equal(re.storage.codes, idx.storage.codes)
    np.testing.assert_array_equal(re.storage.scale, idx.storage.scale)


# ---- streaming: quantized compaction == static quantized build ------------

def test_compacted_quantized_equals_static_quantized_build():
    from repro.streaming import SegmentedIndex
    ds = make_range_dataset(n=260, d=16, n_queries=8, quantize=32, seed=15)
    spec = IndexSpec(variants=("T", "Tp", "Tpp"), m=8, ef_con=40,
                     storage_dtype="int8")
    rng = np.random.default_rng(16)
    s = SegmentedIndex(spec)
    ids = np.arange(260)
    s.add(ids[:150], ds.vectors[:150], ds.lo[:150], ds.hi[:150])
    assert s.flush() is not None
    s.add(ids[150:], ds.vectors[150:], ds.lo[150:], ds.hi[150:])
    dead = rng.choice(260, 20, replace=False)
    s.delete(dead)
    assert s.flush() is not None
    rep = s.compact(full=True)
    assert rep["new_segment"] is not None
    # the surviving segment quantized against the post-churn corpus; a
    # static quantized build over the identical live rows must agree on
    # every route (the re-rank is exact, so ids AND dists match)
    live = np.setdiff1d(ids, dead)
    static = QueryEngine(MSTGIndex.build(spec, ds.vectors[live],
                                         ds.lo[live], ds.hi[live]))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=17)
    for route in ROUTES:
        req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5, ef=48,
                            route=route)
        got = s.search(req)
        want = static.search(req)
        want_ext = np.where(np.asarray(want.ids) >= 0,
                            live[np.clip(np.asarray(want.ids), 0, None)],
                            np.asarray(want.ids, np.int64))
        np.testing.assert_array_equal(np.asarray(got.ids), want_ext,
                                      err_msg=route)
        np.testing.assert_allclose(np.asarray(got.dists),
                                   np.asarray(want.dists), rtol=1e-5,
                                   atol=1e-5, err_msg=route)
    # and the stats roll-up reports the quantized tier
    st = s.stats()
    assert st["storage_dtype"] == "int8"
    assert st["storage_bytes"]["compression_ratio"] > 2.0


# ---- scan builder ----------------------------------------------------------

def test_scan_builder_pruned_equals_bulk(parity_ds):
    """builder="scan" materializes members/entries only (no graphs); its
    pruned route must match the bulk build exactly (both are exhaustive
    over qualifying rows)."""
    ds = parity_ds
    bulk = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"), m=8,
                     ef_con=40)
    scan = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"),
                     builder="scan")
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=19)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5,
                        route="pruned")
    a = QueryEngine(bulk).search(req)
    b = QueryEngine(scan).search(req)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


# ---- sharded deployment ----------------------------------------------------

def test_sharded_deployment_int8(parity_ds):
    from repro.distributed import DeploymentSpec, ShardedDeployment
    ds = parity_ds
    tids = None
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=23)
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries,
                               qlo, qhi, ANY_OVERLAP, 5)
    req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=5, ef=48,
                        route="flat")
    results = {}
    for tier in (None, "int8"):
        spec = DeploymentSpec(
            n_shards=2, engine=EngineConfig(storage_dtype=tier),
            index=IndexSpec(variants=("T",), m=8, ef_con=40))
        dep = ShardedDeployment.build(ds.vectors, ds.lo, ds.hi, spec=spec)
        res = dep.execute(req)
        assert res.report.route == "sharded"
        results[tier] = recall_at_k(np.asarray(res.ids), tids)
    # per-shard quantization + exact per-shard re-rank: parity with f32
    assert results[None] - results["int8"] <= RECALL_DROP_MAX
