"""Wavefront graph-search rework: bit-packed visited sets, chunked
active-batch compaction, and the auto-route parity fix.

The contract under test: every execution mode of the wavefront engine —
packed or dense visited, chunked or single-loop, any fanout — returns results
*bit-identical* (ids AND distances) to the reference single-loop dense-visited
search at the same parameters.
"""
import functools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

import jax.numpy as jnp

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING,
                        LEFT_OVERLAP, RIGHT_OVERLAP, EngineConfig,
                        QueryEngine, SearchRequest, intervals as iv)
from repro.core.search import (mstg_graph_search, mstg_graph_search_chunked,
                               packed_words)
from repro.data import make_queries

MASKS = [
    ANY_OVERLAP,
    QUERY_CONTAINED,
    QUERY_CONTAINING,
    LEFT_OVERLAP,
    RIGHT_OVERLAP,
    LEFT_OVERLAP | RIGHT_OVERLAP,
    QUERY_CONTAINED | QUERY_CONTAINING,
    LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP,
]
ROUTES = ("graph", "pruned", "flat")


@pytest.fixture(scope="module")
def ref_engine(built_index):
    """The seed-equivalent reference: dense visited, single while_loop."""
    return QueryEngine(built_index, config=EngineConfig(packed_visited=False,
                                                        graph_chunk=None))


@pytest.fixture(scope="module")
def wave_engine(built_index):
    """The wavefront path under test: packed visited, forced tiny chunks (so
    compaction triggers even at test batch sizes)."""
    return QueryEngine(built_index, config=EngineConfig(packed_visited=True,
                                                        graph_chunk=7))


def _slot_args(eng, variant_slot, queries):
    dv = eng.graph_dev(variant_slot.variant)
    return (dv.tree(), jnp.asarray(queries),
            jnp.asarray(variant_slot.version, jnp.int32),
            jnp.asarray(variant_slot.key_lo, jnp.int32),
            jnp.asarray(variant_slot.key_hi, jnp.int32)), dv.meta.Kpad


# ---- device level: packed bitmap == dense bool, chunked == single-loop ----

@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
def test_packed_visited_bit_identical(small_ds, built_index, ref_engine, mask):
    ds = small_ds
    qlo, qhi = make_queries(ds, mask, 0.15, seed=3)
    for s in ref_engine.plan(mask, qlo, qhi):
        args, Kpad = _slot_args(ref_engine, s, ds.queries)
        kw = dict(k=10, ef=48, max_steps=250, Kpad=Kpad)
        di, dd = mstg_graph_search(*args, **kw, packed=False)
        pi, pd = mstg_graph_search(*args, **kw, packed=True)
        np.testing.assert_array_equal(np.asarray(di), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(pd))


@functools.lru_cache(maxsize=1)
def _prop_ctx():
    """Tiny dataset + engine for the hypothesis sweeps (fixtures cannot mix
    into @given under the offline fallback shim)."""
    from repro.core import MSTGIndex
    from repro.data import make_range_dataset
    ds = make_range_dataset(n=240, d=12, n_queries=20, quantize=32, seed=2)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp"), m=8,
                    ef_con=32)
    return ds, QueryEngine(idx)


@settings(max_examples=12, deadline=None)
@given(hst.sampled_from([1, 2, 5, 16]), hst.sampled_from([4, 17, 32, 64]),
       hst.sampled_from([1, 2, 3, 4]), hst.sampled_from([1, 3, 8, 50]),
       hst.integers(0, 2**30))
def test_chunked_equals_single_loop(Q, ef, fanout, chunk, seed):
    """Random Q/ef/fanout/chunk: the chunked-compaction driver returns the
    single-loop results bit for bit (ids and distances)."""
    ds, eng = _prop_ctx()
    rng = np.random.default_rng(seed)
    pick = rng.integers(0, ds.queries.shape[0], Q)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=seed % 97)
    queries, qlo, qhi = ds.queries[pick], qlo[pick], qhi[pick]
    max_steps = (4 * ef + 64) // fanout + 8
    for s in eng.plan(ANY_OVERLAP, qlo, qhi):
        args, Kpad = _slot_args(eng, s, queries)
        kw = dict(k=min(10, ef), ef=ef, max_steps=max_steps, Kpad=Kpad,
                  fanout=fanout)
        si, sd = mstg_graph_search(*args, **kw)
        ci, cd = mstg_graph_search_chunked(*args, **kw, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(si), ci)
        np.testing.assert_array_equal(np.asarray(sd), cd)


def test_chunked_stats_account_for_all_rows(small_ds, built_index):
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=5)
    s = eng.plan(ANY_OVERLAP, qlo, qhi)[0]
    args, Kpad = _slot_args(eng, s, ds.queries)
    ids, d, stats = mstg_graph_search_chunked(
        *args, k=10, ef=32, max_steps=200, Kpad=Kpad, chunk=8,
        with_stats=True)
    Q = ds.queries.shape[0]
    assert stats["conv_steps"].shape == (Q,)
    assert (stats["conv_steps"] >= 0).all()
    assert stats["conv_steps"].max() <= stats["steps"]
    assert stats["evals_useful"] <= stats["evals_executed"]
    assert 0.0 <= stats["wasted_eval_frac"] < 1.0


def test_fanout_dedupe_does_not_shadow_vertex_zero():
    """The step dedupe replaces invalid slots with out-of-range sentinels
    before the first-occurrence test: an earlier empty (0-filled) slot must
    not swallow a genuine proposal of corpus vertex 0, while true duplicates
    among valid slots still collapse to their first occurrence."""
    from repro.core.search import _first_occurrence
    n, FS = 10, 4
    cols = jnp.arange(FS, dtype=jnp.int32)[None, :]
    tg = jnp.array([[5, 0, 0, 3]], jnp.int32)    # col 1 invalid, col 2 = id 0
    ok = jnp.array([[True, False, True, True]])
    keep = ok & _first_occurrence(jnp.where(ok, tg, n + cols))
    assert keep.tolist() == [[True, False, True, True]]
    tg2 = jnp.array([[7, 7, 0, 0]], jnp.int32)   # real duplicates
    ok2 = jnp.ones((1, FS), bool)
    keep2 = ok2 & _first_occurrence(jnp.where(ok2, tg2, n + cols))
    assert keep2.tolist() == [[True, False, True, False]]


def test_packed_words_memory_math():
    # the README's Q*n/8-bytes claim: one uint32 word covers 32 vertices
    assert packed_words(1) == 1
    assert packed_words(32) == 1
    assert packed_words(33) == 2
    assert packed_words(800) == 25      # 800 vertices -> 100 bytes/query


# ---- engine level: the full 8-mask x 3-route grid ----

@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
@pytest.mark.parametrize("route", ROUTES)
def test_wavefront_engine_grid_bit_identical(small_ds, ref_engine,
                                             wave_engine, mask, route):
    """Packed + chunked engine == dense + single-loop engine, bit for bit,
    across the canonical masks and all three routes (pinned fanout so both
    engines run the same wavefront width)."""
    ds = small_ds
    qlo, qhi = make_queries(ds, mask, 0.15, seed=13)
    req = SearchRequest(ds.queries, (qlo, qhi), mask, k=10, ef=48,
                        route=route, fanout=2)
    a = ref_engine.search(req)
    b = wave_engine.search(req)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.dists, b.dists)
    assert a.report.route == b.report.route == route


def test_empty_slot_skip_is_result_identical(small_ds, built_index,
                                             ref_engine):
    """A mask whose plan contains an all-empty slot: skipping the slot before
    device work must not change results (QUERY_CONTAINED over a range below
    the domain floor plans an empty task on one variant)."""
    ds = small_ds
    eng = QueryEngine(built_index)
    qlo = np.full(5, float(ds.lo.min()) - 30.0)
    qhi = np.full(5, float(ds.lo.min()) - 20.0)
    for mask in (QUERY_CONTAINED, ANY_OVERLAP):
        req = SearchRequest(ds.queries[:5], (qlo, qhi), mask, k=5,
                            route="graph", fanout=1)
        a = ref_engine.search(req)
        b = eng.search(req)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)


# ---- streaming fan-out inherits the wavefront loop ----

def test_segmented_fanout_inherits_wavefront(small_ds):
    """SegmentedIndex search: packed+chunked per-segment engines return the
    dense+single-loop results bit for bit, under churn (tombstones + delta)."""
    from repro.core import IndexSpec
    from repro.streaming import SegmentedIndex

    ds = small_ds
    n = 220
    spec = IndexSpec(variants=("T", "Tp"), m=8, ef_con=40)

    def build(engine_config):
        seg = SegmentedIndex(spec, engine_config=engine_config)
        ids = np.arange(n)
        seg.add(ids[:150], ds.vectors[:150], ds.lo[:150], ds.hi[:150])
        seg.flush()
        seg.add(ids[150:n], ds.vectors[150:n], ds.lo[150:n], ds.hi[150:n])
        seg.flush()
        seg.delete(np.arange(10, 30))
        seg.add(ids[40:60], ds.vectors[40:60] + 0.25,
                ds.lo[40:60], ds.hi[40:60])          # upserts -> delta
        return seg

    ref = build(EngineConfig(packed_visited=False, graph_chunk=None))
    wave = build(EngineConfig(packed_visited=True, graph_chunk=5))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.25, seed=17)
    for route in ("graph", "pruned"):
        req = SearchRequest(ds.queries, (qlo, qhi), ANY_OVERLAP, k=8, ef=32,
                            route=route, fanout=2)
        a = ref.search(req)
        b = wave.search(req)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)


# ---- selectivity index: exact, and consulted before device work ----

@settings(max_examples=20, deadline=None)
@given(hst.integers(1, 63), hst.integers(0, 2**30))
def test_selectivity_index_exact_vs_predicate_scan(mask, seed):
    rng = np.random.default_rng(seed)
    K = int(rng.integers(2, 60))
    vals = np.sort(rng.choice(5000, K, replace=False)).astype(np.float64)
    dom = iv.AttributeDomain(vals)
    m = int(rng.integers(1, 150))
    lo_r = rng.integers(0, K, m)
    hi_r = np.maximum(lo_r, rng.integers(0, K, m))
    lo, hi = vals[lo_r], vals[hi_r]
    si = iv.SelectivityIndex(lo_r, hi_r, K)
    Q = 25
    ql = rng.uniform(vals[0] - 99, vals[-1] + 99, Q)
    qh = ql + rng.uniform(0, vals[-1] - vals[0], Q) * rng.integers(0, 2, Q)
    fl, cl = dom.floor_rank(ql), dom.ceil_rank(ql)
    fr, cr = dom.floor_rank(qh), dom.ceil_rank(qh)
    want = np.asarray(iv.eval_predicate(
        mask, lo[None, :], hi[None, :], ql[:, None], qh[:, None])).sum(axis=1)
    np.testing.assert_array_equal(si.count(mask, fl, cl, fr, cr), want)


def test_engine_estimates_match_table_and_scan(small_ds, built_index):
    """The engine's table-backed estimator returns exactly what the sample
    scan returned (sample == corpus here, so both are exact)."""
    ds = small_ds
    eng = QueryEngine(built_index)
    assert eng._sel_index is not None
    for mask in MASKS:
        qlo, qhi = make_queries(ds, mask, 0.12, seed=23)
        est = eng.estimate_selectivity(mask, qlo, qhi)
        want = np.stack([np.asarray(iv.eval_predicate(
            mask, ds.lo, ds.hi, qlo[i], qhi[i])).mean()
            for i in range(len(qlo))])
        np.testing.assert_allclose(est, want, atol=1e-12)
