"""Segment-tree decomposition invariants (host + JAX parity)."""
import numpy as np
from hypothesis import given, settings, strategies as hst

import jax.numpy as jnp

from repro.core import segment_tree as st


@settings(max_examples=150, deadline=None)
@given(hst.integers(1, 9), hst.data())
def test_decompose_canonical(logk, data):
    Kpad = 1 << logk
    lo = data.draw(hst.integers(0, Kpad - 1))
    hi = data.draw(hst.integers(0, Kpad - 1))
    nodes = st.decompose(lo, hi, Kpad)
    if lo > hi:
        assert nodes == []
        return
    covered = np.zeros(Kpad, bool)
    per_level = {}
    for lvl, idx in nodes:
        a, b = st.node_range(lvl, idx, Kpad)
        assert not covered[a:b + 1].any(), "nodes overlap"
        covered[a:b + 1] = True
        per_level[lvl] = per_level.get(lvl, 0) + 1
    want = np.zeros(Kpad, bool)
    want[lo:hi + 1] = True
    assert np.array_equal(covered, want), "cover is not exact"
    assert all(c <= 2 for c in per_level.values()), "more than 2 nodes per level"


@settings(max_examples=80, deadline=None)
@given(hst.integers(1, 8), hst.data())
def test_decompose_jax_matches_host(logk, data):
    Kpad = 1 << logk
    lo = data.draw(hst.integers(-2, Kpad + 2))
    hi = data.draw(hst.integers(-2, Kpad + 2))
    levels, idxs, valid = st.decompose_jax(jnp.int32(lo), jnp.int32(hi), Kpad)
    got = sorted((int(l), int(i)) for l, i, v in
                 zip(levels, idxs, valid) if bool(v))
    lo_c, hi_c = max(lo, 0), min(hi, Kpad - 1)
    want = sorted(st.decompose(lo_c, hi_c, Kpad)) if lo_c <= hi_c and lo <= hi else []
    assert got == want


def test_leaf_path():
    Kpad = 16
    nodes = st.leaf_path_nodes(13, Kpad)
    assert nodes[0] == (0, 0)
    assert nodes[-1] == (st.num_levels(Kpad) - 1, 13)
    for lvl, idx in nodes:
        a, b = st.node_range(lvl, idx, Kpad)
        assert a <= 13 <= b


def test_vertex_levels_for_cover():
    Kpad = 16
    lo, hi = 3, 12
    nodes = st.decompose(lo, hi, Kpad)
    P = st.max_cover_nodes(Kpad)
    levels = np.zeros(P, np.int32)
    idxs = np.zeros(P, np.int32)
    valid = np.zeros(P, bool)
    for i, (l, j) in enumerate(nodes):
        levels[i], idxs[i], valid[i] = l, j, True
    tkeys = jnp.arange(Kpad, dtype=jnp.int32)
    lv = st.vertex_levels_for_cover(tkeys, jnp.asarray(levels), jnp.asarray(idxs),
                                    jnp.asarray(valid), Kpad)
    for key in range(Kpad):
        if lo <= key <= hi:
            l = int(lv[key])
            a, b = None, None
            for (nl, nj) in nodes:
                ra, rb = st.node_range(nl, nj, Kpad)
                if ra <= key <= rb:
                    assert nl == l
                    break
        else:
            assert int(lv[key]) == -1
