"""Serving engine: generation determinism, cache seeding, retrieval server."""
import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ANY_OVERLAP, Overlaps, QueryEngine, QueryHit
from repro.data import make_queries, brute_force_topk, recall_at_k
from repro.models.transformer import LM
from repro.serving import RetrievalServer, ServeEngine


def test_generate_runs_and_is_deterministic():
    cfg = configs.get_smoke_config("olmo-1b")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = ServeEngine(lm, params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    g1 = eng.generate(batch, n_new=6, max_len=32)
    g2 = eng.generate(batch, n_new=6, max_len=32)
    np.testing.assert_array_equal(g1.tokens, g2.tokens)
    assert g1.tokens.shape == (2, 6)
    assert (g1.tokens >= 0).all() and (g1.tokens < cfg.vocab).all()


def test_generate_matches_teacher_forcing():
    """Greedy generation must equal argmax over repeated prefill logits."""
    cfg = configs.get_smoke_config("gemma3-1b")  # exercises ring caches
    lm = LM(cfg)
    params = lm.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (1, 16))
    eng = ServeEngine(lm, params)
    out = eng.generate({"tokens": jnp.asarray(toks, jnp.int32)}, n_new=4,
                       max_len=32)
    # reference: roll forward with full prefills
    cur = toks.copy()
    want = []
    for _ in range(4):
        lg, _ = lm.prefill(params, {"tokens": jnp.asarray(cur, jnp.int32)})
        nxt = int(jnp.argmax(lg[0, -1]))
        want.append(nxt)
        cur = np.concatenate([cur, [[nxt]]], axis=1)
    assert out.tokens[0].tolist() == want


def test_retrieval_server_batches_by_mask(small_ds, built_index):
    """Declarative path: Predicate submit, one stacked embed call per tick."""
    ds = small_ds
    embed_calls = []

    def embed(items):  # batched: list of item keys -> (B, d)
        embed_calls.append(list(items))
        return ds.queries[np.asarray(items)]

    server = RetrievalServer(QueryEngine(built_index), embed_fn=embed, k=10)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=4)
    for i in range(8):
        # mixed predicate spellings all land in the same mask group
        server.submit(i, qlo[i], qhi[i],
                      Overlaps() if i % 2 else "any_overlap")
    res = server.tick()
    assert len(res) == 8 and not server.queue
    assert len(embed_calls) == 1 and embed_calls[0] == list(range(8))
    assert all(isinstance(h, QueryHit) for h in res.values())
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:8],
                               qlo[:8], qhi[:8], ANY_OVERLAP, 10)
    found = np.stack([res[i][0] for i in range(8)])  # QueryHit[0] == ids
    assert recall_at_k(found, tids) >= 0.8
    assert server.tick() == {}  # empty tick is a no-op


def test_tick_stats_report_wall_clock_phase_timings(small_ds, built_index):
    """tick() must account its wall-clock time per phase: embed / mutate /
    search durations land in tick_stats and accumulate into stats."""
    ds = small_ds
    server = RetrievalServer(QueryEngine(built_index),
                             embed_fn=lambda items: ds.queries[
                                 np.asarray(items)], k=5)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=4)
    for i in range(4):
        server.submit(i, qlo[i], qhi[i], ANY_OVERLAP)
    server.tick()
    st = server.tick_stats
    for key in ("embed_s", "mutate_s", "search_s", "tick_s"):
        assert key in st and st[key] >= 0.0
    assert st["embed_s"] > 0.0 and st["search_s"] > 0.0
    assert st["mutate_s"] < 0.05               # no mutations: scan-only phase
    assert st["tick_s"] >= st["embed_s"] + st["mutate_s"] + st["search_s"]
    assert server.stats["tick_s"] == st["tick_s"]  # cumulative mirrors
    # an empty tick is a no-op and must not clobber the recorded timings
    assert server.tick() == {}
    assert server.stats["search_s"] == st["search_s"]


def test_retrieval_server_per_item_embed_fallback(small_ds, built_index):
    """Per-item embed_fn (scalar item -> (d,)) still works: the server probes
    once, then falls back to mapping items through the embedder."""
    ds = small_ds

    def embed_one(i):  # per-item embedder (scalar item -> (d,))
        if isinstance(i, list):
            raise TypeError("not batched")
        return ds.queries[i]

    server = RetrievalServer(QueryEngine(built_index), embed_fn=embed_one,
                             k=10)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=4)
    for i in range(4):
        server.submit(i, qlo[i], qhi[i], ANY_OVERLAP)
    res = server.tick()
    assert len(res) == 4 and server._embed_batched is False
    tids, _ = brute_force_topk(ds.vectors, ds.lo, ds.hi, ds.queries[:4],
                               qlo[:4], qhi[:4], ANY_OVERLAP, 10)
    found = np.stack([res[i].ids for i in range(4)])
    assert recall_at_k(found, tids) >= 0.8
