"""Async serving front end: SLO scheduler semantics, slot-refill
bit-identity, mutation interleave, and fault degradation under load.

The headline contract: every hit served by the continuous-batching
:class:`AsyncRetrievalServer` is **bit-identical** (ids AND distances) to
running that query alone through ``engine.execute`` at the same
(k, ef, route, fanout) — admission order, micro-batch grouping, and
mid-flight slot refill must be invisible in the results.
"""
import functools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core import (ANY_OVERLAP, QUERY_CONTAINED, QUERY_CONTAINING,
                        LEFT_OVERLAP, RIGHT_OVERLAP, EngineConfig, Overlaps,
                        QueryEngine, Rejected, SearchRequest, Served,
                        intervals as iv)
from repro.data import make_queries, make_range_dataset
from repro.serving import (AsyncRetrievalServer, DeleteOp, QueryOp, Scheduler,
                           SLOPolicy, StreamingHistogram, UpsertOp)

MASKS = [
    ANY_OVERLAP,
    QUERY_CONTAINED,
    QUERY_CONTAINING,
    LEFT_OVERLAP,
    RIGHT_OVERLAP,
    LEFT_OVERLAP | RIGHT_OVERLAP,
    QUERY_CONTAINED | QUERY_CONTAINING,
    LEFT_OVERLAP | QUERY_CONTAINED | RIGHT_OVERLAP,
]
ROUTES = ("graph", "pruned", "flat")


class FakeClock:
    """Deterministic injectable clock (seconds)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, s: float) -> None:
        self.t += s


def _q(i=0, deadline_ms=None, priority=0):
    return QueryOp(i, 0.0, 1.0, ANY_OVERLAP, deadline_ms=deadline_ms,
                   priority=priority)


# ---- scheduler: bounded admission, dispatch triggers, EDF, shedding ----

def test_bounded_queue_sheds_typed_rejection():
    sch = Scheduler(SLOPolicy(max_queue=2, max_wait_ms=0.0))
    assert isinstance(sch.offer(_q(0)), int)
    assert isinstance(sch.offer(_q(1)), int)
    rej = sch.offer(_q(2))
    assert isinstance(rej, Rejected) and rej.reason == "queue_full"
    assert not rej and rej.queue_depth == 2  # falsy outcome, never raises


def test_due_triggers_max_wait_max_batch_and_mutations():
    clk = FakeClock()
    sch = Scheduler(SLOPolicy(max_wait_ms=5.0, max_batch=3), clock=clk)
    assert not sch.due()                      # empty queue: nothing due
    sch.offer(_q(0))
    assert not sch.due()                      # young single query waits
    clk.advance(0.006)
    assert sch.due()                          # oldest waited past max_wait
    sch2 = Scheduler(SLOPolicy(max_wait_ms=1e9, max_batch=3), clock=clk)
    for i in range(3):
        sch2.offer(_q(i))
    assert sch2.due()                         # full batch dispatches early
    sch3 = Scheduler(SLOPolicy(max_wait_ms=1e9), clock=clk)
    sch3.offer(DeleteOp(7))
    assert sch3.due()                         # mutations never wait


def test_edf_orders_by_deadline_then_priority_then_fifo():
    clk = FakeClock()
    sch = Scheduler(SLOPolicy(max_wait_ms=0.0), clock=clk)
    t_none = sch.offer(_q(0))                     # no deadline -> last
    t_late = sch.offer(_q(1, deadline_ms=500.0))
    t_soon = sch.offer(_q(2, deadline_ms=100.0))
    t_hi = sch.offer(_q(3, priority=5))           # no deadline, high priority
    rnd = sch.next_round()
    assert [e.ticket for e in rnd.queries] == [t_soon, t_late, t_hi, t_none]
    assert not rnd.mutations and not rnd.shed and sch.depth == 0


def test_fifo_when_edf_disabled():
    sch = Scheduler(SLOPolicy(max_wait_ms=0.0, edf=False))
    tickets = [sch.offer(_q(i, deadline_ms=1e3 - i)) for i in range(4)]
    assert [e.ticket for e in sch.next_round().queries] == tickets


def test_expired_entries_shed_at_dispatch():
    clk = FakeClock()
    sch = Scheduler(SLOPolicy(max_wait_ms=0.0), clock=clk)
    t_dead = sch.offer(_q(0, deadline_ms=10.0))
    t_live = sch.offer(_q(1, deadline_ms=1e4))
    clk.advance(0.05)                         # 50ms > 10ms deadline
    rnd = sch.next_round()
    assert [e.ticket for e in rnd.queries] == [t_live]
    (e, rej), = rnd.shed
    assert e.ticket == t_dead and rej.reason == "deadline_expired"
    keep = Scheduler(SLOPolicy(max_wait_ms=0.0, shed_expired=False),
                     clock=clk)
    keep.offer(_q(0, deadline_ms=10.0))
    clk.advance(0.05)
    rnd = keep.next_round()                   # policy off: run it anyway
    assert len(rnd.queries) == 1 and not rnd.shed


def test_mutation_barrier_blocks_query_reordering():
    """EDF may reorder queries among themselves but never across a mutation:
    a query submitted after an upsert must not run in the round before it."""
    sch = Scheduler(SLOPolicy(max_wait_ms=0.0))
    t_q1 = sch.offer(_q(0))                        # before the barrier
    sch.offer(UpsertOp(9, 9, 0.0, 1.0))
    t_urgent = sch.offer(_q(1, deadline_ms=1.0))   # urgent, after barrier
    r1 = sch.next_round()
    assert [e.ticket for e in r1.queries] == [t_q1] and not r1.mutations
    r2 = sch.next_round()
    assert [type(e.op) for e in r2.mutations] == [UpsertOp]
    assert [e.ticket for e in r2.queries] == [t_urgent]


def test_capacity_caps_round_and_close_sheds_shutdown():
    sch = Scheduler(SLOPolicy(max_wait_ms=0.0, max_batch=64))
    for i in range(6):
        sch.offer(_q(i))
    rnd = sch.next_round(capacity=2)
    assert len(rnd.queries) == 2 and sch.depth == 4
    shed = sch.close()
    assert len(shed) == 4
    assert all(r.reason == "shutdown" for _, r in shed)
    assert sch.offer(_q(99)).reason == "shutdown"  # closed: admission off


def test_streaming_histogram_percentiles_bound_samples():
    h = StreamingHistogram()
    vals = [0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0]
    for v in vals:
        h.record(v)
    assert h.count == 10 and h.max_ms == 256.0
    assert abs(h.mean - np.mean(vals)) < 1e-9
    # conservative: the estimate never under-reports the true percentile,
    # and log-spaced bins keep it within one bin width (~9%) above it
    for p in (50, 95, 99):
        true = float(np.percentile(vals, p, method="inverted_cdf"))
        assert true <= h.percentile(p) <= true * 1.12
    assert h.percentile(100) == 256.0
    assert StreamingHistogram().percentile(99) == 0.0


# ---- continuous path: slot refill is invisible in the results ----

@functools.lru_cache(maxsize=1)
def _grid_ctx():
    """Shared tiny corpus + engine for the bit-identity grid (module-scope
    cache; @given-decorated tests cannot take fixtures under the offline
    hypothesis fallback shim)."""
    from repro.core import MSTGIndex
    ds = make_range_dataset(n=240, d=12, n_queries=12, quantize=32, seed=2)
    idx = MSTGIndex(ds.vectors, ds.lo, ds.hi, variants=("T", "Tp", "Tpp"),
                    m=8, ef_con=32)
    return ds, QueryEngine(idx)


def _solo_reference(eng, ds, mask, route, qlo, qhi, k, ef):
    """Each query executed alone — the ground truth the server must match."""
    out = []
    for i in range(len(qlo)):
        res = eng.execute(SearchRequest(
            ds.queries[i:i + 1], (qlo[i:i + 1], qhi[i:i + 1]), mask, k=k,
            ef=ef, route=route))
        out.append((np.asarray(res.ids[0]), np.asarray(res.dists[0])))
    return out


def _serve_in_waves(eng, ds, mask, route, qlo, qhi, k, ef, wave_sizes,
                    steps_between=2):
    """Submit queries in waves with stream steps in between, so later waves
    are admitted into slots freed mid-flight (true refill), then drain."""
    srv = AsyncRetrievalServer(
        eng, lambda items: ds.queries[np.asarray(items)], k=k, ef=ef,
        route=route, max_inflight=16, chunk=3,
        policy=SLOPolicy(max_wait_ms=0.0, max_batch=4))
    tickets = {}
    i = 0
    for w in wave_sizes:
        for _ in range(w):
            if i >= len(qlo):
                break
            tickets[srv.submit(i, qlo[i], qhi[i], mask)] = i
            i += 1
        for _ in range(steps_between):
            srv.step()
    while i < len(qlo):
        tickets[srv.submit(i, qlo[i], qhi[i], mask)] = i
        i += 1
    res = srv.run_until_idle()
    assert set(res) == set(tickets)
    by_query = {}
    for t, out in res.items():
        assert isinstance(out, Served) and out
        by_query[tickets[t]] = out
    return srv, by_query


@pytest.mark.parametrize("mask", MASKS, ids=iv.mask_name)
@pytest.mark.parametrize("route", ROUTES)
def test_async_grid_bit_identical_to_solo(mask, route):
    """8-mask x 3-route grid: staggered admission + slot refill (graph) /
    micro-batching (pruned, flat) returns solo-execution results bit for
    bit."""
    ds, eng = _grid_ctx()
    qlo, qhi = make_queries(ds, mask, 0.2, seed=11)
    k, ef = 8, 24
    want = _solo_reference(eng, ds, mask, route, qlo, qhi, k, ef)
    _, got = _serve_in_waves(eng, ds, mask, route, qlo, qhi, k, ef,
                             wave_sizes=(5, 4, 3))
    assert set(got) == set(range(len(qlo)))
    for i, (wi, wd) in enumerate(want):
        np.testing.assert_array_equal(got[i].hit.ids, wi)
        np.testing.assert_array_equal(got[i].hit.dists, wd)


@settings(max_examples=10, deadline=None)
@given(hst.integers(0, 2**30), hst.sampled_from([1, 2, 3, 5]),
       hst.sampled_from([1, 2, 4]))
def test_async_refill_property_random_waves(seed, wave, steps_between):
    """Random wave shapes and step interleavings on the wavefront path stay
    bit-identical to solo execution (the property behind continuous
    batching: refill changes *when* a row runs, never *what* it computes)."""
    ds, eng = _grid_ctx()
    rng = np.random.default_rng(seed)
    mask = MASKS[int(rng.integers(0, len(MASKS)))]
    qlo, qhi = make_queries(ds, mask, 0.25, seed=seed % 89)
    k, ef = 6, 16
    want = _solo_reference(eng, ds, mask, "graph", qlo, qhi, k, ef)
    _, got = _serve_in_waves(eng, ds, mask, "graph", qlo, qhi, k, ef,
                             wave_sizes=[wave] * 6,
                             steps_between=steps_between)
    for i, (wi, wd) in enumerate(want):
        np.testing.assert_array_equal(got[i].hit.ids, wi)
        np.testing.assert_array_equal(got[i].hit.dists, wd)


def test_refill_actually_happens_and_is_observable():
    """The staggered schedule above must exercise real mid-flight refill —
    otherwise the grid test proves nothing about it."""
    ds, eng = _grid_ctx()
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=11)
    srv, _ = _serve_in_waves(eng, ds, ANY_OVERLAP, "graph", qlo, qhi, 8, 24,
                             wave_sizes=(4, 4, 4), steps_between=3)
    snap = srv.snapshot()
    assert snap["refills"] > 0 and snap["refilled_rows"] > 0
    assert 0.0 < snap["batch_occupancy"] <= 1.0
    assert 0.0 < snap["refill_efficiency"] <= 1.0
    assert snap["served"] == len(qlo) and snap["shed_total"] == 0


# ---- server-level SLO behavior ----

def test_async_deadline_shed_and_missed_flag(small_ds, built_index):
    ds = small_ds
    clk = FakeClock()
    eng = QueryEngine(built_index)
    srv = AsyncRetrievalServer(
        eng, lambda items: ds.queries[np.asarray(items)], k=5, ef=16,
        policy=SLOPolicy(max_wait_ms=0.0), clock=clk)
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=3)
    t_dead = srv.submit(0, qlo[0], qhi[0], ANY_OVERLAP, deadline_ms=5.0)
    t_slow = srv.submit(1, qlo[1], qhi[1], ANY_OVERLAP, deadline_ms=1e7)
    clk.advance(0.05)  # 50ms: t_dead expired in queue, t_slow still live
    res = srv.run_until_idle()
    assert res[t_dead].reason == "deadline_expired"
    assert isinstance(res[t_slow], Served) and not res[t_slow].deadline_missed
    # a request that is dispatched in time but *finishes* past its deadline
    # is served and flagged, never shed: dispatch it (tiny chunk so it stays
    # in flight), then advance the clock past the deadline before draining
    slow = AsyncRetrievalServer(
        eng, lambda items: ds.queries[np.asarray(items)], k=5, ef=16,
        chunk=1, route="graph",                  # wavefront: stays in flight
        policy=SLOPolicy(max_wait_ms=0.0), clock=clk)
    t_late = slow.submit(2, qlo[2], qhi[2], ANY_OVERLAP, deadline_ms=5.0)
    slow.step()                              # dispatched before expiry
    clk.advance(1.0)                         # 1s >> the 5ms deadline
    res = slow.run_until_idle()
    assert isinstance(res[t_late], Served)
    assert res[t_late].deadline_missed
    assert slow.snapshot()["deadline_missed"] == 1
    snap = srv.snapshot()
    assert snap["shed"]["deadline_expired"] == 1
    assert snap["deadline_missed"] == 0 and snap["served"] == 1


def test_async_close_sheds_queue_but_drains_inflight(small_ds, built_index):
    ds = small_ds
    srv = AsyncRetrievalServer(
        QueryEngine(built_index), lambda items: ds.queries[np.asarray(items)],
        k=5, ef=16, policy=SLOPolicy(max_wait_ms=0.0, max_batch=2))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=3)
    tickets = [srv.submit(i, qlo[i], qhi[i], ANY_OVERLAP) for i in range(6)]
    srv.step()                                # dispatches first 2 in-flight
    res = srv.close()
    assert sum(1 for r in res.values() if isinstance(r, Rejected)
               and r.reason == "shutdown") == 4
    assert isinstance(srv.submit(9, qlo[0], qhi[0], ANY_OVERLAP), Rejected)
    final = srv.run_until_idle()              # in-flight pair still completes
    served = [t for t in tickets if isinstance(final.get(t), Served)]
    assert len(served) == 2


def test_async_step_stats_and_metrics_shape(small_ds, built_index):
    ds = small_ds
    srv = AsyncRetrievalServer(
        QueryEngine(built_index), lambda items: ds.queries[np.asarray(items)],
        k=5, ef=16, policy=SLOPolicy(max_wait_ms=0.0))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.2, seed=3)
    for i in range(4):
        srv.submit(i, qlo[i], qhi[i], ANY_OVERLAP)
    srv.run_until_idle()
    st = srv.step_stats
    for key in ("dispatched", "served", "shed", "admitted_rows",
                "harvested_rows", "queue_depth", "inflight", "step_s"):
        assert key in st
    snap = srv.snapshot()
    assert snap["submitted"] == snap["admitted"] == 4
    assert snap["served"] == 4
    assert snap["e2e_ms"]["p99"] >= snap["e2e_ms"]["p50"] > 0.0
    assert snap["queue_wait_ms"]["max"] >= 0.0


# ---- composition: mutable + sharded backends through the async path ----

def test_segmented_mutations_interleave_with_queries(small_ds):
    """SegmentedIndex behind the scheduler: a query submitted after an upsert
    sees it (barrier), and the upserted vector is retrievable; deletes
    submitted after a query do not affect it."""
    from repro.core import IndexSpec
    from repro.streaming import SegmentedIndex

    ds = small_ds
    n = 300
    seg = SegmentedIndex(IndexSpec(variants=("T", "Tp"), m=8, ef_con=40))
    seg.add(np.arange(n), ds.vectors[:n], ds.lo[:n], ds.hi[:n])
    seg.flush()
    probe = ds.vectors[5] + 1e-4 * np.ones_like(ds.vectors[5])

    def embed(items):
        return np.stack([probe if it == "probe" else ds.queries[it]
                         for it in items])

    srv = AsyncRetrievalServer(seg, embed, k=5, ef=32,
                               policy=SLOPolicy(max_wait_ms=0.0))
    assert srv.mutable
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=7)
    t_before = srv.submit(0, float(ds.lo.min()), float(ds.hi.max()),
                          ANY_OVERLAP)
    t_up = srv.submit_upsert(7777, "probe", float(ds.lo.min()),
                             float(ds.hi.max()))
    t_after = srv.submit("probe", float(ds.lo.min()), float(ds.hi.max()),
                         ANY_OVERLAP)
    t_del = srv.submit_delete(7777)
    res = srv.run_until_idle()
    assert all(isinstance(res[t], Served)
               for t in (t_before, t_up, t_after, t_del))
    assert res[t_up].hit is None and res[t_del].hit is None
    assert 7777 not in res[t_before].hit.ids       # barrier: not yet visible
    assert res[t_after].hit.ids[0] == 7777         # nearest to its own vector
    assert srv.snapshot()["mutations"] == 2


def test_frozen_backend_rejects_mutations_not_mutable(small_ds, built_index):
    ds = small_ds
    srv = AsyncRetrievalServer(
        QueryEngine(built_index), lambda items: ds.queries[np.asarray(items)])
    rej = srv.submit_upsert(1, 0, 0.0, 1.0)
    assert isinstance(rej, Rejected) and rej.reason == "not_mutable"
    assert srv.submit_delete(1).reason == "not_mutable"
    assert srv.snapshot()["shed"]["not_mutable"] == 2


def test_shard_loss_mid_stream_degrades_without_stalling(small_ds):
    """ShardedDeployment behind the scheduler: kill a shard between waves —
    later responses flag degraded=True, earlier ones don't, the scheduler
    keeps serving (no stall, no raise), and restore() heals."""
    from repro.distributed import DeploymentSpec, ShardedDeployment

    ds = small_ds
    dep = ShardedDeployment.flat(ds.vectors, ds.lo, ds.hi,
                                 spec=DeploymentSpec(n_shards=4))
    srv = AsyncRetrievalServer(
        dep, lambda items: ds.queries[np.asarray(items)], k=8, ef=32,
        policy=SLOPolicy(max_wait_ms=0.0, max_batch=4))
    qlo, qhi = make_queries(ds, ANY_OVERLAP, 0.3, seed=6)
    wave1 = [srv.submit(i, qlo[i], qhi[i], ANY_OVERLAP) for i in range(4)]
    r1 = srv.run_until_idle()
    dep.fail(2)                                    # mid-stream shard loss
    wave2 = [srv.submit(i, qlo[i], qhi[i], ANY_OVERLAP) for i in range(4, 8)]
    r2 = srv.run_until_idle()
    dep.restore(2)
    wave3 = [srv.submit(i, qlo[i], qhi[i], ANY_OVERLAP) for i in range(8, 12)]
    r3 = srv.run_until_idle()
    for t in wave1:
        assert isinstance(r1[t], Served) and not r1[t].degraded
    for t in wave2:
        assert isinstance(r2[t], Served) and r2[t].degraded
        assert r2[t].hit.ids.shape == (8,)         # degraded, still answers
    for t in wave3:
        assert isinstance(r3[t], Served) and not r3[t].degraded
    snap = srv.snapshot()
    assert snap["served"] == 12 and snap["degraded"] == 4
    assert snap["shed_total"] == 0                 # loss never sheds
