"""Streaming subsystem: add/delete/flush/compact equivalence vs a static
build (all routes, 8+ predicate masks), tombstone-exact fan-out search,
manifest save/load bit-identity (including the unflushed delta), compaction
policy, server-side mutations, and the exp11 update-recall gate."""
import os
import sys

import numpy as np
import pytest

from repro.checkpoint import IndexIOError
from repro.core import (IndexSpec, MSTGIndex, QueryEngine, SearchRequest,
                        intervals as iv)
from repro.data import (RangeDataset, brute_force_topk, make_queries,
                        make_range_dataset, recall_at_k)
from repro.streaming import CompactionPolicy, DeltaBuffer, SegmentedIndex

N = 280
N_SEG1 = 160
# >= 8 predicate masks covering every atomic case, disjunctions, and the
# Allen relations (acceptance criterion a)
MASKS8 = (1, 2, 4, 8, 15, 16, 32, 48)


def _to_ext(ids, ext_of_row):
    """Map a static index's row ids to external ids (NO_EDGE passes through)."""
    return np.where(ids >= 0, ext_of_row[np.clip(ids, 0, None)],
                    np.asarray(ids, np.int64))


@pytest.fixture(scope="module")
def sds():
    return make_range_dataset(n=N, d=16, n_queries=8, quantize=32, seed=5)


@pytest.fixture(scope="module")
def spec():
    return IndexSpec(variants=("T", "Tp", "Tpp"), m=8, ef_con=40)


def _streaming_ops(sds, spec):
    """The canonical op sequence: 2 waves of adds, deletes in both the frozen
    segment and the delta, upserts of frozen rows, 2 flushes. Returns the
    index plus the expected live corpus keyed by external id."""
    rng = np.random.default_rng(11)
    s = SegmentedIndex(spec)
    ids = np.arange(N)
    s.add(ids[:N_SEG1], sds.vectors[:N_SEG1], sds.lo[:N_SEG1],
          sds.hi[:N_SEG1])
    assert s.flush() is not None
    s.add(ids[N_SEG1:], sds.vectors[N_SEG1:], sds.lo[N_SEG1:],
          sds.hi[N_SEG1:])
    dead = np.concatenate([rng.choice(N_SEG1, 12, replace=False),
                           N_SEG1 + rng.choice(N - N_SEG1, 8, replace=False)])
    assert s.delete(dead) == len(dead)
    up = rng.choice(np.setdiff1d(np.arange(N_SEG1), dead), 6, replace=False)
    upv = (sds.vectors[up]
           + 0.05 * rng.normal(0, 1, (6, sds.d)).astype(np.float32))
    s.add(up, upv, sds.lo[up], sds.hi[up])  # upsert frozen rows
    assert s.flush() is not None

    corpus = {int(i): (sds.vectors[i], sds.lo[i], sds.hi[i])
              for i in range(N)}
    for e in dead:
        corpus.pop(int(e))
    for j, e in enumerate(up):
        corpus[int(e)] = (upv[j], sds.lo[e], sds.hi[e])
    return s, corpus


def _live_arrays(corpus):
    live = np.array(sorted(corpus), np.int64)
    vecs = np.stack([corpus[int(e)][0] for e in live])
    lo = np.array([corpus[int(e)][1] for e in live])
    hi = np.array([corpus[int(e)][2] for e in live])
    return live, vecs, lo, hi


@pytest.fixture(scope="module")
def compacted(sds, spec):
    """Full lifecycle ending in compact(full=True) -> one clean segment."""
    s, corpus = _streaming_ops(sds, spec)
    rep = s.compact(full=True)
    assert rep["new_segment"] is not None and rep["dropped"] > 0
    return s, corpus


@pytest.fixture(scope="module")
def static_equiv(compacted, spec):
    """From-scratch MSTGIndex.build over the identical live corpus in the
    canonical (external id) order."""
    _, corpus = compacted
    live, vecs, lo, hi = _live_arrays(corpus)
    return QueryEngine(MSTGIndex.build(spec, vecs, lo, hi)), live, vecs, lo, hi


# ---- acceptance (a): streamed == static on all routes, >= 8 masks ----

@pytest.mark.parametrize("mask", MASKS8)
def test_compacted_equals_static_build_all_routes(compacted, static_equiv,
                                                  sds, mask):
    s, corpus = compacted
    eng, live, vecs, lo, hi = static_equiv
    ds = RangeDataset(vectors=vecs, lo=lo, hi=hi, queries=sds.queries,
                      span=sds.span)
    qlo, qhi = make_queries(ds, mask, 0.15, seed=mask)
    for route in ("graph", "pruned", "flat"):
        req = SearchRequest(sds.queries, (qlo, qhi), mask, k=5, ef=64,
                            route=route)
        got = s.search(req)
        want = eng.search(req)
        np.testing.assert_array_equal(
            got.ids, _to_ext(want.ids, live),
            err_msg=f"{iv.mask_name(mask)}/{route}")
        np.testing.assert_array_equal(got.dists, want.dists,
                                      err_msg=f"{iv.mask_name(mask)}/{route}")
        assert got.report.route == "segmented"
        assert got.report.requested == route
        assert len(got.report.segments) == 1
        assert got.report.segments[0].route == route
        assert got.report.segments[0].tombstones == 0


# ---- mid-stream (segments + tombstones + live delta) ----

@pytest.fixture(scope="module")
def midstream(sds, spec):
    s, corpus = _streaming_ops(sds, spec)
    # leave extra churn unflushed: delete a frozen row, upsert two delta rows
    s.delete(np.array([40]))
    corpus.pop(40)
    rng = np.random.default_rng(21)
    up = np.array([200, 230])
    upv = (sds.vectors[up]
           + 0.05 * rng.normal(0, 1, (2, sds.d)).astype(np.float32))
    s.add(up, upv, sds.lo[up], sds.hi[up])
    for j, e in enumerate(up):
        corpus[int(e)] = (upv[j], sds.lo[e], sds.hi[e])
    assert len(s.segments) == 2 and len(s.delta) == 2
    return s, corpus


@pytest.mark.parametrize("mask", (15, 2, 48))
def test_midstream_exact_routes_match_brute_force(midstream, sds, mask):
    """With tombstones live in frozen segments plus an unflushed delta, the
    exact routes stay recall-1.0: the per-segment k+|tombstones| over-fetch
    means filtering can never evict a true neighbor."""
    s, corpus = midstream
    live, vecs, lo, hi = _live_arrays(corpus)
    ds = RangeDataset(vectors=vecs, lo=lo, hi=hi, queries=sds.queries,
                      span=sds.span)
    qlo, qhi = make_queries(ds, mask, 0.2, seed=100 + mask)
    tids, tds = brute_force_topk(vecs, lo, hi, sds.queries, qlo, qhi, mask, 5)
    truth_ext = _to_ext(tids, live)
    for route in ("pruned", "flat"):
        res = s.search(SearchRequest(sds.queries, (qlo, qhi), mask, k=5,
                                     route=route))
        assert recall_at_k(res.ids, truth_ext) == 1.0, (mask, route)
        np.testing.assert_allclose(np.sort(res.dists, 1), np.sort(tds, 1),
                                   rtol=1e-4, atol=1e-4)
    # graph route is approximate but must stay strong through the fan-out
    res = s.search(SearchRequest(sds.queries, (qlo, qhi), mask, k=5, ef=96,
                                 route="graph"))
    assert recall_at_k(res.ids, truth_ext) >= 0.9
    segs = {r.segment: r for r in res.report.segments}
    assert "delta" in segs and segs["delta"].route == "delta"
    tombed = [r for r in res.report.segments if r.tombstones]
    assert tombed and all(r.k_fetched > 5 for r in tombed)


# ---- acceptance (b): save/load bit-identity ----

def test_save_load_bit_identical_including_delta_and_tombstones(
        midstream, sds, tmp_path):
    s, corpus = midstream
    root = os.path.join(tmp_path, "seg_idx")
    manifest_path = s.save(root)
    assert manifest_path.endswith("manifest.json")
    delta_files = [f for f in os.listdir(root)
                   if f.startswith("delta-") and f.endswith(".npz")]
    assert len(delta_files) == 1  # content-named, referenced by the manifest
    t = SegmentedIndex.load(root)
    st_a, st_b = s.stats(), t.stats()
    assert st_a["n_live"] == st_b["n_live"] == len(corpus)
    assert st_a["segments"] == st_b["segments"]
    assert st_a["delta"] == st_b["delta"]
    live, vecs, lo, hi = _live_arrays(corpus)
    ds = RangeDataset(vectors=vecs, lo=lo, hi=hi, queries=sds.queries,
                      span=sds.span)
    for mask in (15, 8, 32):
        qlo, qhi = make_queries(ds, mask, 0.15, seed=mask)
        for route in ("graph", "pruned", "flat"):
            req = SearchRequest(sds.queries, (qlo, qhi), mask, k=6,
                                route=route)
            a, b = s.search(req), t.search(req)
            np.testing.assert_array_equal(a.ids, b.ids, err_msg=f"{mask}/{route}")
            np.testing.assert_array_equal(a.dists, b.dists,
                                          err_msg=f"{mask}/{route}")


def test_resave_by_different_index_is_never_stale(sds, spec, tmp_path):
    """Two fresh SegmentedIndex instances mint the same counter-derived
    segment ids; saving both into one directory must not let the second
    manifest reference the first index's data (files are content-named)."""
    root = os.path.join(tmp_path, "idx")
    a = SegmentedIndex(spec)
    a.add(np.arange(60), sds.vectors[:60], sds.lo[:60], sds.hi[:60])
    a.flush()
    a.save(root)
    b = SegmentedIndex(spec)
    b.add(np.arange(60, 120), sds.vectors[60:120], sds.lo[60:120],
          sds.hi[60:120])
    b.flush()
    assert b.segments[0].seg_id == a.segments[0].seg_id  # id collision
    b.save(root)
    t = SegmentedIndex.load(root)
    assert sorted(e for e in range(200) if e in t) == list(range(60, 120))
    req = SearchRequest(sds.queries, (np.full(8, sds.lo.min()),
                                      np.full(8, sds.hi.max())), 15, k=4,
                        route="flat")
    want, got = b.search(req), t.search(req)
    np.testing.assert_array_equal(want.ids, got.ids)
    np.testing.assert_array_equal(want.dists, got.dists)
    assert (got.ids[got.ids >= 0] >= 60).all()


def test_save_load_failure_paths(midstream, tmp_path):
    s, _ = midstream
    root = os.path.join(tmp_path, "seg_idx")
    s.save(root)
    # corrupting one segment file surfaces as IndexIOError, not KeyError/zip
    seg_file = os.path.join(
        root, "segments", sorted(os.listdir(os.path.join(root, "segments")))[0])
    with open(seg_file, "wb") as f:
        f.write(b"not a zip archive")
    with pytest.raises(IndexIOError):
        SegmentedIndex.load(root)
    with pytest.raises(IndexIOError):
        SegmentedIndex.load(os.path.join(tmp_path, "no_such_dir"))


# ---- unit: delta buffer ----

def test_delta_buffer_upsert_kill_and_search():
    rng = np.random.default_rng(0)
    d = DeltaBuffer()
    vecs = rng.normal(0, 1, (5, 8)).astype(np.float32)
    d.add(np.arange(5), vecs, np.zeros(5), np.ones(5))
    assert len(d) == 5 and 3 in d and 9 not in d
    assert d.kill(3) and not d.kill(3)  # idempotent
    d.add(np.array([1]), vecs[:1] * 2, np.array([5.0]), np.array([6.0]))
    assert len(d) == 4 and d.n_dead == 2  # killed 3, upserted-over 1
    ext, dv, dlo, dhi = d.live()
    assert list(ext) == [0, 2, 4, 1]  # arrival order, dead rows gone
    assert dlo[-1] == 5.0
    # search only sees live rows; query range [0, 1] excludes the new id 1
    ids, dist = d.search(vecs[:2], np.zeros(2), np.ones(2), 15, k=4)
    assert ids.shape == (2, 4)
    assert set(ids[ids >= 0].tolist()) <= {0, 2, 4}
    with pytest.raises(ValueError):
        d.add(np.array([7, 7]), vecs[:2], np.zeros(2), np.ones(2))
    with pytest.raises(ValueError):
        d.add(np.array([8]), rng.normal(0, 1, (1, 4)).astype(np.float32),
              np.zeros(1), np.ones(1))  # dim mismatch
    with pytest.raises(ValueError):
        d.add(np.array([9]), vecs[:1], np.ones(1), np.zeros(1))  # lo > hi


# ---- unit: compaction policy ----

def test_compaction_policy_pick():
    p = CompactionPolicy(tier_ratio=4.0, min_merge=2, max_merge=3)
    assert p.pick([]) == []
    assert p.pick([100]) == []                      # nothing to merge with
    assert p.pick([10, 12]) == [0, 1]               # one small tier
    assert p.pick([10, 12, 1000]) == [0, 1]         # big segment left alone
    assert set(p.pick([5, 1000, 7, 900, 6])) == {0, 2, 4}
    assert p.pick([0, 1000]) == [0]                 # dead weight always goes
    picked = p.pick([3, 0, 1000, 2])
    assert picked[0] == 1 and set(picked) == {1, 0, 3}  # dead first, then tier
    assert len(p.pick([1, 1, 1, 1, 1])) == 3        # max_merge cap
    with pytest.raises(ValueError):
        CompactionPolicy(tier_ratio=0.5)
    with pytest.raises(ValueError):
        CompactionPolicy(min_merge=1)


def test_size_tiered_compact_merges_small_segments(sds, spec):
    s = SegmentedIndex(spec, policy=CompactionPolicy(tier_ratio=4.0))
    ids = np.arange(N)
    for a, b in ((0, 120), (120, 150), (150, 180)):
        s.add(ids[a:b], sds.vectors[a:b], sds.lo[a:b], sds.hi[a:b])
        s.flush()
    assert [g.n for g in s.segments] == [120, 30, 30]
    rep = s.compact()  # policy merges the two 30s, leaves the 120 alone
    assert rep["rows"] == 60 and len(s.segments) == 2
    assert {g.n for g in s.segments} == {120, 60}
    assert len(s) == 180
    rep2 = s.compact()  # smallest tier is now {60, 120} within ratio 4
    assert rep2["rows"] == 180 and len(s.segments) == 1


# ---- upsert/delete bookkeeping ----

def test_upsert_delete_bookkeeping(sds, spec):
    s = SegmentedIndex(spec, flush_threshold=50)
    s.add(np.arange(50), sds.vectors[:50], sds.lo[:50], sds.hi[:50])
    assert len(s.segments) == 1 and len(s.delta) == 0  # auto-flush fired
    assert 10 in s and len(s) == 50
    s.delete(10)
    assert 10 not in s and len(s) == 49
    with pytest.raises(KeyError):
        s.delete(10)                     # already gone, strict by default
    assert s.delete(10, strict=False) == 0
    s.add(np.array([10]), sds.vectors[10:11], sds.lo[10:11], sds.hi[10:11])
    assert 10 in s and len(s) == 50      # re-add after delete
    res = s.search(SearchRequest(sds.vectors[10:11],
                                 [[sds.lo[10], sds.hi[10]]], 15, k=1))
    assert res.ids[0, 0] == 10           # the re-added copy is findable
    with pytest.raises(TypeError):
        s.execute("not a request")


def test_rejected_upsert_batch_leaves_old_rows_live(sds, spec):
    """A batch that fails validation must not tombstone/kill the rows it
    would have replaced (validate-before-discard)."""
    s = SegmentedIndex(spec)
    s.add(np.arange(40), sds.vectors[:40], sds.lo[:40], sds.hi[:40])
    s.flush()
    s.add(np.arange(40, 44), sds.vectors[40:44], sds.lo[40:44], sds.hi[40:44])
    before = len(s)
    with pytest.raises(ValueError):        # inverted range
        s.add(np.array([5, 41]), sds.vectors[:2],
              np.array([1.0, 3.0]), np.array([2.0, 2.0]))
    with pytest.raises(ValueError):        # in-batch duplicate ids
        s.add(np.array([5, 5]), sds.vectors[:2], sds.lo[:2], sds.hi[:2])
    with pytest.raises(ValueError):        # dim mismatch
        s.add(np.array([5]), np.zeros((1, sds.d + 1), np.float32),
              sds.lo[:1], sds.hi[:1])
    assert len(s) == before and 5 in s and 41 in s
    assert not s.segments[0].tombs and s.delta.n_dead == 0


def test_graph_route_overfetch_raises_ef_past_tombstones(sds, spec):
    """With more tombstones than the request's ef, the per-segment beam pool
    must widen with k_eff or filtering would evict every live neighbor."""
    s = SegmentedIndex(spec)
    s.add(np.arange(24), sds.vectors[:24], sds.lo[:24], sds.hi[:24])
    s.flush()
    q = sds.vectors[:1]
    d2 = ((sds.vectors[:24] - q) ** 2).sum(1)
    s.delete(np.argsort(d2)[:8])           # kill the 8 nearest to the query
    live = np.array(sorted(e for e in range(24) if e in s))
    full = (float(sds.lo[:24].min()), float(sds.hi[:24].max()))
    res = s.search(SearchRequest(q, [full], 15, k=5, ef=5, route="graph"))
    assert res.report.segments[0].k_fetched == 13   # 5 + 8 tombstones
    got = res.ids[0][res.ids[0] >= 0]
    assert len(got) == 5                   # 5 live hits despite ef=5 request
    assert set(got.tolist()) <= set(live.tolist())
    want = live[np.argsort(((sds.vectors[live] - q) ** 2).sum(1))[:5]]
    # beam search is approximate; without the ef raise ~0 live hits survive
    assert len(set(got.tolist()) & set(want.tolist())) >= 4


# ---- serving integration ----

def test_retrieval_server_applies_mutations_before_queries(sds, spec):
    from repro.serving import RetrievalServer

    s = SegmentedIndex(spec)
    s.add(np.arange(100), sds.vectors[:100], sds.lo[:100], sds.hi[:100])
    s.flush()
    embed_calls = []

    def embed(items):
        embed_calls.append(list(items))
        return np.stack([sds.vectors[i] for i in items])

    server = RetrievalServer(s, embed_fn=embed, k=3)
    assert server.mutable
    # query for object 120's own vector over its exact range: only findable
    # if the upsert submitted in the same tick lands first
    server.submit_upsert(120, 120, float(sds.lo[120]), float(sds.hi[120]))
    server.submit_delete(7)
    server.submit(120, float(sds.lo[120]), float(sds.hi[120]), "any_overlap")
    res = server.tick()
    assert len(embed_calls) == 1 and embed_calls[0] == [120, 120]
    assert list(res) == [2]              # only the query slot answers
    assert res[2].ids[0] == 120
    assert 7 not in s and 120 in s
    # frozen engines refuse mutations at submit time
    static = RetrievalServer(QueryEngine(MSTGIndex.build(
        spec, sds.vectors[:60], sds.lo[:60], sds.hi[:60])), embed_fn=embed)
    assert not static.mutable
    with pytest.raises(TypeError):
        static.submit_upsert(1, 1, 0.0, 1.0)
    with pytest.raises(TypeError):
        static.submit_delete(1)


def test_retrieval_server_auto_compacts_per_policy(sds):
    """tick() runs policy-gated background compaction after mutations: two
    flush-threshold segments appear across ticks, the policy merges them,
    and the counters land in tick_stats/stats."""
    from repro.serving import RetrievalServer
    spec = IndexSpec(variants=("T", "Tp"), m=8, ef_con=40)
    s = SegmentedIndex(spec, policy=CompactionPolicy(tier_ratio=4.0),
                       flush_threshold=20)

    def embed(items):
        return np.stack([sds.vectors[i] for i in items])

    server = RetrievalServer(s, embed_fn=embed, k=3)
    for i in range(20):
        server.submit_upsert(i, i, float(sds.lo[i]), float(sds.hi[i]))
    server.tick()
    # one segment: a single tombstone-free victim is never merged
    assert server.tick_stats["upserts"] == 20
    assert server.tick_stats["compactions"] == 0
    assert len(s.segments) == 1
    for i in range(20, 40):
        server.submit_upsert(i, i, float(sds.lo[i]), float(sds.hi[i]))
    server.tick()
    # the second flush created a same-size tier -> auto-compacted to one
    assert server.tick_stats["compactions"] == 1
    assert server.tick_stats["compacted_rows"] == 40
    assert server.stats["compactions"] == 1 and server.stats["upserts"] == 40
    assert len(s.segments) == 1 and s.segments[0].n == 40
    # an idle tick resets tick_stats instead of replaying the last tick's
    assert server.tick() == {}
    assert server.tick_stats == server._zero_stats()
    # auto_compact=False restores manual-only compaction
    s2 = SegmentedIndex(spec, flush_threshold=10)
    manual = RetrievalServer(s2, embed_fn=embed, k=3, auto_compact=False)
    for i in range(20):
        manual.submit_upsert(i, i, float(sds.lo[i]), float(sds.hi[i]))
    manual.tick()
    assert len(s2.segments) == 2 and manual.stats["compactions"] == 0


def test_compact_full_with_bulk_builder_matches_static_rebuild(sds):
    """Satellite: a compact(full=True) whose segments froze via the bulk
    builder equals a static bulk MSTGIndex.build over the live corpus."""
    spec = IndexSpec(variants=("T", "Tp"), m=8, ef_con=40, builder="bulk")
    s = SegmentedIndex(spec)
    s.add(np.arange(100), sds.vectors[:100], sds.lo[:100], sds.hi[:100])
    s.flush()
    s.add(np.arange(100, 160), sds.vectors[100:160], sds.lo[100:160],
          sds.hi[100:160])
    s.flush()
    s.delete(np.arange(20))
    rep = s.compact(full=True)
    assert rep["new_segment"] is not None and rep["dropped"] == 20
    assert s.segments[0].index.spec.builder == "bulk"
    live = np.arange(20, 160)
    eng = QueryEngine(MSTGIndex.build(spec, sds.vectors[20:160],
                                      sds.lo[20:160], sds.hi[20:160]))
    ds = RangeDataset(vectors=sds.vectors[20:160], lo=sds.lo[20:160],
                      hi=sds.hi[20:160], queries=sds.queries, span=sds.span)
    qlo, qhi = make_queries(ds, iv.ANY_OVERLAP, 0.15, seed=2)
    for route in ("graph", "pruned"):
        req = SearchRequest(sds.queries, (qlo, qhi), iv.ANY_OVERLAP, k=5,
                            ef=64, route=route)
        got, want = s.search(req), eng.search(req)
        np.testing.assert_array_equal(got.ids, _to_ext(want.ids, live),
                                      err_msg=route)
        np.testing.assert_array_equal(got.dists, want.dists, err_msg=route)


def test_builder_knob_travels_through_manifest(sds, tmp_path):
    """The spec's builder/batch_size fields round-trip through save/load so
    future flushes/compactions keep using the pinned construction path."""
    spec = IndexSpec(variants=("T",), m=8, ef_con=40, builder="bulk",
                     batch_size=64)
    s = SegmentedIndex(spec)
    s.add(np.arange(30), sds.vectors[:30], sds.lo[:30], sds.hi[:30])
    root = str(tmp_path / "seg")
    s.save(root)
    r = SegmentedIndex.load(root)
    assert r.spec.builder == "bulk" and r.spec.batch_size == 64
    assert IndexSpec().builder == "bulk"  # bulk is the fleet-wide default


# ---- acceptance (c): exp11 smoke gate ----

def test_exp11_update_benchmark_smoke():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.exp11_updates import RECALL_GATE, run_churn
    r = run_churn(n=240, d=16, n_queries=8, k=5,
                  spec=IndexSpec(variants=("T", "Tp"), m=8, ef_con=40))
    assert r["update_ops_per_sec"] > 0
    assert r["query_qps_streamed"] > 0
    assert r["inserted"] == 24 and r["deleted"] == 12
    assert r["update_recall"] >= RECALL_GATE >= 0.95
    assert r["compacted_rows"] == 240 + 24 - 12
