"""End-to-end system tests: the examples run, the dry-run pipeline works on a
small subprocess mesh, plan->search->serve composes."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=900, env_extra=None):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    if env_extra:
        env.update(env_extra)
    return subprocess.run(args, capture_output=True, text=True,
                          timeout=timeout, cwd=ROOT, env=env)


@pytest.mark.slow
def test_example_quickstart():
    r = _run([sys.executable, "examples/quickstart.py"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "recall@10" in r.stdout


@pytest.mark.slow
def test_example_train_lm():
    r = _run([sys.executable, "examples/train_lm.py", "--arch", "olmo-1b",
              "--steps", "12", "--batch", "2", "--seq", "64"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "final loss" in r.stdout


@pytest.mark.slow
def test_example_distributed_serving():
    r = _run([sys.executable, "examples/distributed_serving.py"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tournament" in r.stdout


@pytest.mark.slow
def test_dryrun_pipeline_small_mesh():
    """The dry-run machinery end to end on an 8-device placeholder mesh
    (the 512-device production run is a launch artifact, exercised by
    `python -m repro.launch.dryrun`; its cell results live in artifacts/)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        import jax
        from repro.configs import get_smoke_config, TRAIN_4K, DECODE_32K
        import dataclasses
        from repro.launch.steps import ArchRunner
        from repro.launch.dryrun import collective_bytes
        from repro.launch.mesh import make_mesh
        from repro.configs.base import ShapeConfig

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke_config("olmo-1b")
        shape = ShapeConfig("t", 64, 8, "train")
        runner = ArchRunner(cfg, mesh)
        b = runner.train_bundle(shape)
        with mesh:
            c = jax.jit(b.fn, in_shardings=b.in_shardings,
                        out_shardings=b.out_shardings,
                        donate_argnums=b.donate).lower(*b.args).compile()
        from repro.launch.compat import cost_analysis_dict
        ca = cost_analysis_dict(c)
        assert ca["flops"] > 0
        colls, wire, counts = collective_bytes(c.as_text(), 8)
        assert sum(counts.values()) > 0, "expected collectives on a 3-axis mesh"
        shape = ShapeConfig("d", 64, 8, "decode")
        b = runner.decode_bundle(shape)
        with mesh:
            c = jax.jit(b.fn, in_shardings=b.in_shardings,
                        donate_argnums=b.donate).lower(*b.args).compile()
        print("DRYRUN-PIPELINE-OK")
    """)
    r = _run([sys.executable, "-c", prog])
    assert "DRYRUN-PIPELINE-OK" in r.stdout, r.stdout + r.stderr


def test_production_dryrun_artifacts_exist_and_clean():
    """The committed 512-device dry-run artifacts must cover all 40 cells on
    both meshes with no errors (33 ok + 7 documented skips per mesh)."""
    import json
    adir = os.path.join(ROOT, "artifacts", "dryrun")
    if not os.path.isdir(adir):
        pytest.skip("dry-run artifacts not generated yet")
    cells = [f for f in os.listdir(adir)
             if f.endswith(".json") and not f.startswith("mstg-flat-serve")]
    assert len(cells) == 80, f"expected 80 cell artifacts, got {len(cells)}"
    status = {"ok": 0, "skipped": 0, "error": 0}
    for f in cells:
        with open(os.path.join(adir, f)) as fh:
            rec = json.load(fh)
        status[rec["status"]] = status.get(rec["status"], 0) + 1
    assert status["error"] == 0, status
    assert status["ok"] == 66 and status["skipped"] == 14, status
