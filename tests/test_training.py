"""Training substrate: optimizer math, grad compression, microbatching."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import TokenLoader
from repro.models.transformer import LM
from repro.training import (AdamWConfig, adamw_init, adamw_update,
                            clip_by_global_norm, make_train_step,
                            quantize_int8, dequantize_int8)
from repro.training.grad_compression import compressed_grad_sync, init_residuals


def test_adamw_first_step_is_lr_sized():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 0.5)}
    state = adamw_init(params)
    new, state = adamw_update(cfg, params, grads, state)
    # bias-corrected first Adam step == lr * sign-ish step
    delta = np.asarray(params["w"] - new["w"])
    np.testing.assert_allclose(delta, 1e-2, rtol=1e-3)
    assert int(state["step"]) == 1


def test_grad_clip():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) > 1.0
    from repro.training.optimizer import global_norm
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-6


def test_compressed_sync_single_shard_with_error_feedback():
    """On a 1-device axis the compressed mean must equal plain quantization,
    and error feedback must cancel bias over repeated steps."""
    import jax.experimental.shard_map as shm
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, (64,))
                          .astype(np.float32))}
    res = init_residuals(g)

    def run(gw, rw):
        out, nr = compressed_grad_sync({"w": gw}, "data", {"w": rw})
        return out["w"], nr["w"]

    f = shm.shard_map(run, mesh=mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()), check_rep=False)
    acc = jnp.zeros_like(g["w"])
    r = res["w"]
    for _ in range(16):
        o, r = f(g["w"], r)
        acc = acc + o
    # mean of 16 compressed syncs of the same grad ~ the grad (EF kills bias)
    np.testing.assert_allclose(np.asarray(acc / 16), np.asarray(g["w"]),
                               atol=0.02)


def test_microbatch_equals_full_batch():
    cfg = configs.get_smoke_config("olmo-1b").scaled(n_layers=2, vocab=64)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    loader = TokenLoader(vocab=cfg.vocab, batch=8, seq_len=32, seed=2)
    batch = loader.batch_at(0)
    s1 = make_train_step(lm, opt_cfg=AdamWConfig(lr=1e-3), microbatches=1)
    s2 = make_train_step(lm, opt_cfg=AdamWConfig(lr=1e-3), microbatches=4)
    from repro.training import adamw_init
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
